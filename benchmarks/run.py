"""Benchmark harness — one module per paper table + the roofline summary.

Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run table3     # one table
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    want = sys.argv[1:] or ["table1", "table2", "table3", "roofline"]
    from benchmarks import (table1_profiling, table2_stop_restart,
                            table3_scheduler_sim, roofline)
    mods = {"table1": table1_profiling, "table2": table2_stop_restart,
            "table3": table3_scheduler_sim, "roofline": roofline}
    print("name,us_per_call,derived")
    for name in want:
        t0 = time.perf_counter()
        mods[name].main(csv=print)
        print(f"{name}/wall_s,{(time.perf_counter()-t0)*1e6:.0f},done",
              flush=True)


if __name__ == "__main__":
    main()
