"""Benchmark harness — one module per paper table + perf benches.

Prints ``name,us_per_call,derived`` CSV lines; ``--json out.json``
additionally writes the same rows as machine-readable JSON
(``{name: {us_per_call, derived}}``).

  PYTHONPATH=src python -m benchmarks.run                    # all tables
  PYTHONPATH=src python -m benchmarks.run table3             # one table
  PYTHONPATH=src python -m benchmarks.run scheduler --json out.json
"""
from __future__ import annotations

import json
import sys
import time


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            raise SystemExit("--json requires an output path")
        del argv[i:i + 2]
    want = argv or ["table1", "table2", "table3", "roofline"]
    from benchmarks import (bench_scheduler, roofline, table1_profiling,
                            table2_stop_restart, table3_scheduler_sim)
    mods = {"table1": table1_profiling, "table2": table2_stop_restart,
            "table3": table3_scheduler_sim, "roofline": roofline,
            "scheduler": bench_scheduler}
    unknown = [n for n in want if n not in mods]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}; "
                         f"choose from {sorted(mods)}")
    rows: dict[str, dict] = {}

    def emit(line: str) -> None:
        print(line, flush=True)
        name, us, derived = line.split(",", 2)
        rows[name] = {"us_per_call": float(us), "derived": derived}

    print("name,us_per_call,derived")
    for name in want:
        t0 = time.perf_counter()
        mods[name].main(csv=emit)
        emit(f"{name}/wall_s,{(time.perf_counter() - t0) * 1e6:.0f},done")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(rows, fh, indent=2, sort_keys=True)
            fh.write("\n")


if __name__ == "__main__":
    main()
