"""Table 3 — average JCT (hours) per strategy x contention, simulated on a
64-GPU cluster with Poisson arrivals (§7), next to the paper's numbers."""
from __future__ import annotations

from repro.core.simulator import run_table3

PAPER = {
    "extreme": {"precompute": 7.63, "exploratory": 20.42, "fixed_8": 22.76,
                "fixed_4": 12.90, "fixed_2": 11.49, "fixed_1": 10.10},
    "moderate": {"precompute": 2.63, "exploratory": 2.92, "fixed_8": 6.20,
                 "fixed_4": 3.50, "fixed_2": 4.58, "fixed_1": 6.32},
    "none": {"precompute": 1.40, "exploratory": 1.47, "fixed_8": 1.40,
             "fixed_4": 2.21, "fixed_2": 3.78, "fixed_1": 6.37},
}


def run(seed: int = 0):
    return run_table3(seed=seed)


def main(csv=print):
    ours = run()
    for level in ("extreme", "moderate", "none"):
        for strat in ("precompute", "exploratory", "fixed_8", "fixed_4",
                      "fixed_2", "fixed_1"):
            csv(f"table3/{level}/{strat},0,"
                f"ours_h={ours[level][strat]:.2f};"
                f"paper_h={PAPER[level][strat]:.2f}")
    # headline claims
    m = ours["moderate"]
    csv(f"table3/moderate_speedup_vs_eight,0,"
        f"ours={m['fixed_8']/m['precompute']:.2f}x;"
        f"paper={PAPER['moderate']['fixed_8']/PAPER['moderate']['precompute']:.2f}x")
    return ours


if __name__ == "__main__":
    main()
