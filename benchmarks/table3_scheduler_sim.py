"""Table 3 — average JCT (hours) per strategy x contention, simulated on a
64-GPU cluster (§7), next to the paper's numbers — then the same sweep per
workload pattern (bursty / diurnal / heavy-tailed / mixed max_w fleets)
from the pattern library, which is where the abstract's "on some workload
patterns" claim actually gets exercised, a non-flat cluster scenario
(8-GPU nodes, 10x slower cross-node links, GADGET-style contention
penalty) where the flat-cluster ranking visibly reshuffles, and the
placement-engine scenarios (fragmented and heterogeneous node-level
clusters) where placement-aware strategies beat placement-blind ones."""
from __future__ import annotations

import dataclasses

from repro.collectives.cost import (ClusterModel, INFINIBAND_100G, NodeSpec)
from repro.core.jobs import WORKLOAD_PATTERNS
from repro.core.simulator import TABLE3_STRATEGIES, run_table3

PAPER = {
    "extreme": {"precompute": 7.63, "exploratory": 20.42, "fixed_8": 22.76,
                "fixed_4": 12.90, "fixed_2": 11.49, "fixed_1": 10.10},
    "moderate": {"precompute": 2.63, "exploratory": 2.92, "fixed_8": 6.20,
                 "fixed_4": 3.50, "fixed_2": 4.58, "fixed_1": 6.32},
    "none": {"precompute": 1.40, "exploratory": 1.47, "fixed_8": 1.40,
             "fixed_4": 2.21, "fixed_2": 3.78, "fixed_1": 6.37},
}
# The paper's own six columns; run_table3 additionally sweeps the registry
# extensions (srtf, utility_greedy) — see TABLE3_STRATEGIES.
STRATEGIES = ("precompute", "exploratory", "fixed_8", "fixed_4", "fixed_2",
              "fixed_1")

# The non-flat acceptance scenario: 8 GPUs per node on the paper's 100G
# fabric, 10 Gbit/s-class cross-node links (10x slower per byte), and a
# 5% per-concurrent-ring contention penalty (GADGET, arXiv 2202.01158).
MULTINODE = ClusterModel(capacity=64, gpus_per_node=8,
                         inter_node_beta=1.0 / 1.25e9,
                         contention_penalty=0.05)

# ---------------------------------------------------------------------------
# Placement-engine scenarios (PR 4).  The fragmented cluster: 8-GPU nodes
# on 1 Gbit/s-class cross-node links (80x slower per byte — spanning rings
# really pay), contention on the shared fabric, the contention-aware
# best-fit placement strategy and the migration/defrag pass.  The
# heterogeneous fleet: four current-gen nodes listed first (packed fills
# them first) plus four nodes of older hosts at 1/4 the link and reduce
# throughput.  Swept on the ``mixed_maxw`` pattern (per-job caps up to 16,
# so placement-blind policies happily build node-spanning rings).
# ---------------------------------------------------------------------------
FRAGMENTED = ClusterModel(capacity=64, gpus_per_node=8,
                          inter_node_beta=1.0 / 1.25e8,
                          contention_penalty=0.05,
                          placement="best_fit", defrag=True)
SLOW_NODE_HW = dataclasses.replace(INFINIBAND_100G, beta=4.0 / 12.5e9,
                                   gamma=4.0 / 50e9, name="ib_25g_class")
HETEROGENEOUS = ClusterModel(
    capacity=64,
    nodes=tuple([NodeSpec(8)] * 4 + [NodeSpec(8, hw=SLOW_NODE_HW)] * 4),
    inter_node_beta=1.0 / 1.25e8, contention_penalty=0.05,
    placement="packed")
PLACEMENT_SCENARIOS = {
    "frag_best_fit": FRAGMENTED,
    "frag_no_defrag": dataclasses.replace(FRAGMENTED, defrag=False),
    "frag_spread": dataclasses.replace(FRAGMENTED, placement="spread",
                                       defrag=False),
    "hetero_packed": HETEROGENEOUS,
}
# placement-aware (pack_*) strategies next to their placement-blind twins
PLACEMENT_STRATEGIES = ("precompute", "pack_precompute", "srtf",
                        "pack_srtf", "fixed_8", "utility_greedy")

# ---------------------------------------------------------------------------
# Churn scenarios (PR 10).  The fragmented cluster under deterministic
# fault injection: stochastic node churn, a correlated rack outage, and
# permanent stragglers, swept on the ``mixed_maxw`` pattern (node-spanning
# rings are exactly what a node failure punishes — every gang with a slot
# on the dead node is evicted and loses un-checkpointed progress).  JCT
# alone hides that cost, so these rows also score *goodput*: useful
# progress-seconds per busy GPU-second, net of rolled-back work and
# restart freezes.
# ---------------------------------------------------------------------------
CHURN_SCENARIOS = {
    "churn_6": dataclasses.replace(FRAGMENTED, faults="churn_6",
                                   fault_seed=7, checkpoint_interval=200.0),
    "churn_12": dataclasses.replace(FRAGMENTED, faults="churn_12",
                                    fault_seed=7, checkpoint_interval=200.0),
    "rack_7000": dataclasses.replace(FRAGMENTED, faults="rack_7000",
                                     fault_seed=7,
                                     checkpoint_interval=200.0),
    "stragglers_2": dataclasses.replace(FRAGMENTED, faults="stragglers_2",
                                        fault_seed=7,
                                        checkpoint_interval=200.0),
}
# blind baselines against the failure-aware policy
CHURN_STRATEGIES = ("precompute", "srtf", "pack_srtf", "recovery_aware")


def run_churn(seed: int = 0) -> dict[str, dict[str, dict[str, float]]]:
    """Per-churn-scenario sweep: avg JCT (hours), goodput and eviction
    count per strategy on the moderate ``mixed_maxw`` trace."""
    from repro.core import telemetry
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    jobs = make_workload("mixed_maxw", 114, 500.0, seed)
    out: dict[str, dict[str, dict[str, float]]] = {}
    for name, cluster in CHURN_SCENARIOS.items():
        row = {}
        for strat in CHURN_STRATEGIES:
            res = simulate(jobs, cluster=cluster, strategy=strat,
                           telemetry=telemetry.Telemetry())
            jct = [res.completion_times[j] - res.arrival_times[j]
                   for j in res.completion_times]
            row[strat] = {"jct_h": sum(jct) / len(jct) / 3600.0,
                          "goodput": res.telemetry.goodput,
                          "evictions": float(res.evictions)}
        out[name] = row
    return out


def run(seed: int = 0):
    return run_table3(seed=seed)


def run_decision_counters(seed: int = 0) -> dict[str, dict[str, int]]:
    """Per-strategy decision counters on the paper's moderate trace:
    solver effort (solve reuse rate, heap traffic) behind each Table-3
    column, collected with counters-only telemetry — the trajectory is
    bit-identical to the uninstrumented sweep (gated by the parity
    suite)."""
    from repro.core import telemetry
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    jobs = make_workload("poisson", 114, 500.0, seed)
    out = {}
    for strat in TABLE3_STRATEGIES:
        res = simulate(jobs, 64, strat, telemetry=telemetry.Telemetry())
        out[strat] = res.telemetry.counters
    return out


def run_patterns(seed: int = 0) -> dict[str, dict[str, float]]:
    """Moderate-contention Table-3 row per workload pattern."""
    out = {}
    for pattern in sorted(WORKLOAD_PATTERNS):
        row = run_table3(seed=seed, pattern=pattern,
                         contention={"moderate": (500.0, 114)})
        out[pattern] = row["moderate"]
    return out


def run_multinode(seed: int = 0) -> dict[str, float]:
    """Moderate-contention row on the MULTINODE cluster (all strategies)."""
    row = run_table3(seed=seed, cluster=MULTINODE,
                     contention={"moderate": (500.0, 114)})
    return row["moderate"]


def run_placement(seed: int = 0) -> dict[str, dict[str, float]]:
    """Moderate-contention ``mixed_maxw`` row per placement scenario:
    placement-aware (pack_*) strategies against their placement-blind
    twins on fragmented and heterogeneous node-level clusters."""
    out = {}
    for name, cluster in PLACEMENT_SCENARIOS.items():
        row = run_table3(seed=seed, pattern="mixed_maxw", cluster=cluster,
                         strategies=PLACEMENT_STRATEGIES,
                         contention={"moderate": (500.0, 114)})
        out[name] = row["moderate"]
    return out


def main(csv=print):
    ours = run()
    for level in ("extreme", "moderate", "none"):
        for strat in TABLE3_STRATEGIES:
            paper = PAPER[level].get(strat)
            suffix = "" if paper is None else f";paper_h={paper:.2f}"
            csv(f"table3/{level}/{strat},0,"
                f"ours_h={ours[level][strat]:.2f}{suffix}")
    # headline claims
    m = ours["moderate"]
    csv(f"table3/moderate_speedup_vs_eight,0,"
        f"ours={m['fixed_8']/m['precompute']:.2f}x;"
        f"paper={PAPER['moderate']['fixed_8']/PAPER['moderate']['precompute']:.2f}x")
    # per-pattern rows (moderate contention): the "some workload patterns"
    # claim — report precompute's edge over the best *and* worst fixed-w
    for pattern, row in run_patterns().items():
        fixed = {k: v for k, v in row.items() if k.startswith("fixed")}
        best_fixed = min(fixed.values())
        worst_fixed = max(fixed.values())
        csv(f"table3/pattern/{pattern},0,"
            f"precompute_h={row['precompute']:.2f};"
            f"vs_best_fixed={best_fixed / row['precompute']:.2f}x;"
            f"vs_worst_fixed={worst_fixed / row['precompute']:.2f}x")
    # the non-flat scenario: once links and contention enter the model,
    # the flat-cluster ranking is not a given (GADGET's point)
    mrow = run_multinode()
    for strat in TABLE3_STRATEGIES:
        csv(f"table3/multinode/{strat},0,ours_h={mrow[strat]:.2f}")
    best = min(mrow, key=mrow.get)
    csv(f"table3/multinode_best,0,{best}={mrow[best]:.2f}h;"
        f"precompute={mrow['precompute']:.2f}h")
    # placement-engine scenarios: spanning/contention status now derives
    # from the actual gang assignment under fragmentation, so
    # placement-aware strategies (pack_*) visibly beat their
    # placement-blind twins (the acceptance row for PR 4)
    for name, row in run_placement().items():
        for strat in PLACEMENT_STRATEGIES:
            csv(f"table3/placement/{name}/{strat},0,"
                f"ours_h={row[strat]:.2f}")
        csv(f"table3/placement/{name}/aware_vs_blind,0,"
            f"srtf={row['srtf'] / row['pack_srtf']:.2f}x;"
            f"precompute="
            f"{row['precompute'] / row['pack_precompute']:.2f}x")
    # churn scenarios: every policy scored on JCT *and* goodput under
    # deterministic fault injection (the robustness acceptance rows —
    # recovery_aware should beat blind srtf on goodput under churn)
    for name, row in run_churn().items():
        for strat in CHURN_STRATEGIES:
            m = row[strat]
            csv(f"table3/churn/{name}/{strat},0,"
                f"ours_h={m['jct_h']:.2f};goodput={m['goodput']:.4f};"
                f"evictions={int(m['evictions'])}")
        csv(f"table3/churn/{name}/recovery_vs_srtf,0,goodput="
            f"{row['recovery_aware']['goodput'] / row['srtf']['goodput']:.3f}x")
    # per-strategy decision counters (telemetry layer): the solver-effort
    # story behind the JCT columns — e.g. solve.reused / solve.calls is
    # the cross-tick reuse rate the incremental core banks on
    for strat, ctrs in run_decision_counters().items():
        kv = ";".join(f"{k}={v}" for k, v in sorted(ctrs.items()))
        csv(f"table3/decision_counters/{strat},0,{kv}")
    return ours


if __name__ == "__main__":
    main()
