"""Table 1 — profiling ResNet fwd/back per worker count.

Measures real fwd/back wall time of our JAX ResNet on this host (reduced
depth so CPU stays tractable), scales the global batch with w exactly as
the paper does (m = per-worker batch fixed), and adds the analytic
all-reduce term from eqs. (2)-(4) for the distributed part.  Prints our
columns next to the paper's measured K40m numbers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives import cost as C
from repro.configs.resnet110 import ResNetConfig
from repro.data.synthetic import CifarLike
from repro.models.resnet import ResNetModel
from repro.models.spec import n_params

PAPER = {1: (108.0, 236.5, 402.5, 318.0), 2: (110.2, 274.6, 427.2, 576.2),
         4: (107.1, 290.1, 444.3, 1152.4), 8: (106.0, 307.4, 470.2, 2177.8)}


def run(m_per_worker: int = 16, depth: int = 20, reps: int = 3):
    cfg = ResNetConfig(name=f"resnet{depth}-bench", depth=depth, width=16)
    model = ResNetModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_bytes = n_params(model.param_specs()) * 4
    data = CifarLike(size=4096, seed=0)

    fwd = jax.jit(lambda p, b: model.loss(p, b))
    bwd = jax.jit(jax.grad(model.loss))

    rows = []
    for w in (1, 2, 4, 8):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(0, m_per_worker * w).items()}
        # measure per-worker compute: per-worker batch slice
        local = {k: v[:m_per_worker] for k, v in batch.items()}
        fwd(params, local).block_until_ready()
        jax.block_until_ready(bwd(params, local))
        t0 = time.perf_counter()
        for _ in range(reps):
            fwd(params, local).block_until_ready()
        t_fwd = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(bwd(params, local))
        t_fwdback = (time.perf_counter() - t0) / reps
        t_back = max(t_fwdback - t_fwd, 1e-9)
        t_comm = C.step_time(1, 0.0, 0.0, w, n_bytes, C.TPU_V5E)
        t_total = t_fwdback + t_comm
        imgs = m_per_worker * w / t_total
        rows.append({
            "w": w, "t_fwd_ms": t_fwd * 1e3, "t_back_ms": t_back * 1e3,
            "t_total_ms": t_total * 1e3, "imgs_per_s": imgs,
            "paper_total_ms": PAPER[w][2], "paper_imgs_per_s": PAPER[w][3],
        })
    # scaling efficiency 4->8 (paper: 94.5%)
    eff = rows[3]["imgs_per_s"] / (2 * rows[2]["imgs_per_s"])
    return rows, eff


def main(csv=print):
    rows, eff = run()
    for r in rows:
        csv(f"table1/w={r['w']},{r['t_total_ms']*1e3:.0f},"
            f"imgs_per_s={r['imgs_per_s']:.1f};paper={r['paper_imgs_per_s']}")
    csv(f"table1/scaling_efficiency_4to8,0,ours={eff:.3f};paper=0.945")
    return rows


if __name__ == "__main__":
    main()
