"""Roofline summary: aggregates the dry-run JSON records
(experiments/dryrun/*.json) into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import json
import os
import glob

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load(dirname: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict], mesh: str = "16x16",
          profile: str = "baseline") -> list[dict]:
    rows = []
    for r in recs:
        if r["mesh"] != mesh or r.get("profile", "baseline") != profile:
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_ms": roof["compute_s"] * 1e3,
            "memory_ms": roof["memory_s"] * 1e3,
            "collective_ms": roof["collective_s"] * 1e3,
            "dominant": roof["dominant"],
            "mem_gib": r["memory"]["peak_bytes_per_device"] / 2 ** 30,
            "useful": r["useful_flops_ratio"],
        })
    return rows


def main(csv=print):
    recs = load()
    if not recs:
        csv("roofline/no_records,0,run repro.launch.dryrun first")
        return []
    rows = table(recs)
    for r in rows:
        csv(f"roofline/{r['arch']}/{r['shape']},0,"
            f"compute_ms={r['compute_ms']:.2f};memory_ms={r['memory_ms']:.2f};"
            f"coll_ms={r['collective_ms']:.2f};dom={r['dominant']};"
            f"mem_gib={r['mem_gib']:.2f};useful={r['useful'] or 0:.3f}")
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    csv(f"roofline/dominant_counts,0,{doms}")
    return rows


if __name__ == "__main__":
    main()
