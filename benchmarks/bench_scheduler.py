"""Scheduler hot-path benchmark: SoA solvers + simulator vs seed.

Times (a) single allocation solves, (b) full ``simulate()`` runs — the
60-job parity workload plus 1000-job traces per strategy and per workload
pattern — and (c) ``run_table3`` sweeps at several job counts, each
against the preserved reference implementations (the
``repro.core._reference`` parity oracle: the seed ``*_ref`` solvers and
the ``engine="reference"`` event loop — the seed's cost profile),
asserting allocation-for-allocation and completion-time bit-identity
along the way.  The engine-parity gates iterate the policy registry, so
a newly registered policy is parity-checked automatically.

Writes ``BENCH_scheduler.json`` at the repo root with schema

    {name: {"us_per_call": float, "speedup_vs_seed": float?,
            "peak_rss_mb": float?},
     "_host": {...}}

(``speedup_vs_seed`` is present only where the reference side was timed —
rows with no seed counterpart, like the 1000/10000-job traces the seed
loop cannot finish in reasonable time, simply omit the field instead of
recording a misleading null).  The distinguished ``_host`` entry records
the machine the numbers came from — CPU model, core count,
Python/numpy versions, and the reference-engine machine scale — so
floor baselines stop being guessed from commit-message archaeology.
``peak_rss_mb`` rides on the large-trace rows (10k and up): the
process-lifetime RSS high-water mark observed right after that trace
size ran (sizes run in increasing order, so each value reads as "memory
needed to get through this size").

    PYTHONPATH=src python -m benchmarks.bench_scheduler
    PYTHONPATH=src python -m benchmarks.bench_scheduler --profile-100k
    PYTHONPATH=src python -m benchmarks.bench_scheduler --profile-1m
    PYTHONPATH=src python -m benchmarks.bench_scheduler --check       # CI gate
    PYTHONPATH=src python -m benchmarks.bench_scheduler --check-10k   # forced
    PYTHONPATH=src python -m benchmarks.bench_scheduler --check-100k  # forced
    PYTHONPATH=src python -m benchmarks.run scheduler --json out.json

``--check`` runs every parity assertion (solver allocations, engine
trajectory bit-identity on the 60-job workload and on each workload
pattern — via ``assert_trace_parity``, which compares completion times,
peak concurrency, migrations and rejections at every site) but no timing
loops and no JSON write — seconds, not minutes, so CI can gate on it per
PR.  The parity block includes the telemetry gates (trajectories
bit-identical with telemetry on vs off, event schemas, cross-engine
utilization equality).  It finishes with the gated 10k-job floor (srtf
>= 5x over the PR-4 baseline, machine-normalized against the frozen
reference engine) plus the telemetry-overhead gate (10k-job srtf with
telemetry on <= 1.3x off) and then the 100k-job floor
(machine-normalized wall ceiling per strategy),
each only while the earlier checks left wall-clock budget for it;
``--check-10k`` forces the 10k gate unconditionally and ``--check-100k``
forces both floors (the non-blocking full-suite lane).
``--profile-100k`` / ``--profile-1m`` add the non-gating
``simulate/100000jobs/*`` / ``simulate/1000000jobs/*`` rows to the
timed run.
"""
from __future__ import annotations

import gc
import hashlib
import json
import os
import time

import numpy as np

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_scheduler.json")


def _peak_rss_mb() -> float:
    """Process-lifetime peak RSS in MiB (``ru_maxrss`` is KiB on Linux,
    bytes on macOS).  A monotone high-water mark — callers sample it
    after each trace size, in increasing size order, so the per-size
    numbers read as cumulative footprint, not per-size deltas."""
    import resource
    import sys
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform == "darwin":
        return peak / (1024.0 * 1024.0)
    return peak / 1024.0


def _host_metadata(machine_scale: float | None = None) -> dict:
    """The ``_host`` entry for ``BENCH_scheduler.json``: enough machine
    identity to interpret the absolute numbers (CPU model, core count,
    interpreter/numpy versions) plus the measured reference-engine scale
    relative to the PR-4 baseline machine, so the committed floors can be
    re-derived instead of guessed from comments."""
    import platform
    cpu_model = ""
    try:
        with open("/proc/cpuinfo") as fh:
            for line in fh:
                if line.startswith("model name"):
                    cpu_model = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    if not cpu_model:
        cpu_model = platform.processor() or platform.machine()
    meta = {
        "cpu_model": cpu_model,
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "numpy": np.__version__,
    }
    if machine_scale is not None:
        meta["machine_scale_vs_pr4_baseline"] = machine_scale
    return meta


def _time(fn, min_repeats: int = 3, budget_s: float = 2.0) -> float:
    """Best-of-N wall time of fn() in seconds."""
    best = float("inf")
    t_start = time.perf_counter()
    reps = 0
    while reps < min_repeats or (time.perf_counter() - t_start < budget_s
                                 and reps < 50):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
        reps += 1
    return best


def _record(results, csv, name, fast_s, seed_s=None):
    speedup = None if seed_s is None else seed_s / fast_s
    results[name] = {"us_per_call": fast_s * 1e6}
    if speedup is not None:
        results[name]["speedup_vs_seed"] = speedup
    csv(f"{name},{fast_s * 1e6:.0f},"
        f"speedup_vs_seed={'%.1fx' % speedup if speedup else 'n/a'}")


def _check_solvers(n_jobs: int) -> None:
    """Allocation parity: SoA + table solvers vs the seed ``*_ref`` scan."""
    from repro.core import _reference as R
    from repro.core import scheduler as S
    from repro.core.jobs import JobSpec

    rng = np.random.default_rng(n_jobs)
    specs = [JobSpec(job_id=j, arrival=0.0,
                     epochs=float(rng.uniform(100, 200)))
             for j in range(n_jobs)]
    jc = [(s.job_id, s.epochs, s.speed) for s in specs]
    jt = [(s.job_id, s.epochs, s.speed_table(8).tolist()) for s in specs]
    for name, table_fn, ref_fn in (
            ("doubling", S.doubling_heuristic_table,
             R.doubling_heuristic_ref),
            ("optimus", S.optimus_greedy_table, R.optimus_greedy_ref)):
        assert table_fn(jt, 64, max_w=8) == ref_fn(jc, 64, max_w=8), (
            f"solver parity broken: {name} J={n_jobs}")
    Q = np.array([s.epochs for s in specs])
    tables = np.stack([s.speed_table(8) for s in specs])
    soa = S.doubling_heuristic_soa(Q, tables, 64, max_w=8)
    want = R.doubling_heuristic_ref(jc, 64, max_w=8)
    assert {s.job_id: int(w) for s, w in zip(specs, soa)} == want, (
        f"SoA solver parity broken: doubling J={n_jobs}")


def bench_solvers(results, csv) -> None:
    from repro.core import _reference as R
    from repro.core import scheduler as S
    from repro.core.jobs import JobSpec

    for n_jobs in (10, 30, 60):
        _check_solvers(n_jobs)
        rng = np.random.default_rng(n_jobs)
        specs = [JobSpec(job_id=j, arrival=0.0,
                         epochs=float(rng.uniform(100, 200)))
                 for j in range(n_jobs)]
        jc = [(s.job_id, s.epochs, s.speed) for s in specs]
        jt = [(s.job_id, s.epochs, s.speed_table(8).tolist()) for s in specs]
        for name, table_fn, ref_fn in (
                ("doubling", S.doubling_heuristic_table,
                 R.doubling_heuristic_ref),
                ("optimus", S.optimus_greedy_table, R.optimus_greedy_ref)):
            fast_s = _time(lambda: table_fn(jt, 64, max_w=8))
            seed_s = _time(lambda: ref_fn(jc, 64, max_w=8))
            _record(results, csv, f"solver/{name}/J={n_jobs}", fast_s,
                    seed_s)


PARITY_STRATEGIES = ("precompute", "exploratory", "fixed_8")


def assert_trace_parity(fast, seed, strat: str, context: str = "") -> None:
    """Assert two ``SimResult`` trajectories are bit-identical — every
    observable, not just completion times (the old per-site blocks each
    compared a different subset; migrations/rejected were only checked on
    one of six)."""
    where = f"simulate({strat}){' ' + context if context else ''}"
    assert fast.completion_times == seed.completion_times, (
        f"{where}: completion times diverged")
    assert fast.peak_concurrency == seed.peak_concurrency, (
        f"{where}: peak concurrency diverged")
    assert fast.migrations == seed.migrations, (
        f"{where}: migration counts diverged")
    assert fast.rejected == seed.rejected, (
        f"{where}: rejected-arrival sets diverged")
    assert fast.evictions == seed.evictions, (
        f"{where}: eviction counts diverged")


def _check_simulate_parity() -> None:
    """60-job engine bit-identity for every registered policy (the CI
    gate).  Iterating ``registered_policies()`` means a newly registered
    policy is parity-gated automatically — no benchmark edit needed."""
    from repro.core.jobs import synthetic_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate

    jobs = synthetic_workload(60, 500.0, 0)
    for strat in registered_policies().values():
        fast = simulate(jobs, 64, strat, engine="table")
        seed = simulate(jobs, 64, strat, engine="reference")
        assert_trace_parity(fast, seed, strat, "vs the seed event loop")


def _check_cluster_parity(n_jobs: int = 40) -> None:
    """Engine bit-identity on a non-flat ClusterModel (multi-node topology
    + GADGET-style contention), every registered policy."""
    from repro.collectives.cost import ClusterModel
    from repro.core.jobs import synthetic_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate

    cluster = ClusterModel(capacity=64, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e9,
                           contention_penalty=0.05)
    jobs = synthetic_workload(n_jobs, 500.0, 1)
    for strat in registered_policies().values():
        fast = simulate(jobs, strategy=strat, cluster=cluster)
        seed = simulate(jobs, strategy=strat, cluster=cluster,
                        engine="reference")
        assert_trace_parity(fast, seed, strat, "on the non-flat cluster")


def _check_placement_parity(n_jobs: int = 40) -> None:
    """Placement-engine gates: (a) on a flat cluster the engine is a
    bit-identical no-op for every registered policy; (b) on a fragmented
    node-level cluster (placement + defrag + admission running) both
    simulator engines agree bit-for-bit, every registered policy."""
    from repro.collectives.cost import ClusterModel
    from repro.core.jobs import make_workload, synthetic_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate

    flat_placed = ClusterModel(capacity=64, placement="packed")
    jobs = synthetic_workload(n_jobs, 500.0, 1)
    for strat in registered_policies().values():
        plain = simulate(jobs, 64, strat)
        placed = simulate(jobs, strategy=strat, cluster=flat_placed)
        assert_trace_parity(placed, plain, strat,
                            "flat-cluster placement no-op")
    cluster = ClusterModel(capacity=64, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e8,
                           contention_penalty=0.05,
                           placement="best_fit", defrag=True,
                           admission="free_gpus_2")
    pjobs = make_workload("mixed_maxw", n_jobs, 500.0, 3)
    for strat in registered_policies().values():
        fast = simulate(pjobs, strategy=strat, cluster=cluster)
        seed = simulate(pjobs, strategy=strat, cluster=cluster,
                        engine="reference")
        assert_trace_parity(fast, seed, strat, "on the placement cluster")


def _check_telemetry(n_jobs: int = 60) -> None:
    """Telemetry gates: (a) recording a run changes nothing — trajectories
    with telemetry on are bit-identical to off, every registered policy;
    (b) every emitted event validates against its schema; (c) the
    time-weighted utilization agrees bitwise between the two engines and
    is ``None`` exactly when telemetry is off."""
    from repro.core import telemetry as tele
    from repro.core.jobs import synthetic_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate

    jobs = synthetic_workload(n_jobs, 500.0, 0)
    for strat in registered_policies().values():
        off = simulate(jobs, 64, strat)
        on = simulate(jobs, 64, strat,
                      telemetry=tele.Telemetry(sink=tele.MemorySink()))
        assert_trace_parity(on, off, strat, "with telemetry on vs off")
        assert off.telemetry is None and off.utilization is None, (
            f"simulate({strat}): telemetry off must leave SimResult"
            f".telemetry None")
        assert on.telemetry is not None and on.utilization is not None, (
            f"simulate({strat}): telemetry on produced no rollup")
        for ev in on.telemetry.events:
            tele.validate_event(ev)
        ref = simulate(jobs, 64, strat, engine="reference",
                       telemetry=tele.Telemetry())
        assert ref.utilization == on.utilization, (
            f"simulate({strat}): utilization diverged between engines: "
            f"table={on.utilization!r} reference={ref.utilization!r}")


def _check_pattern_parity(n_jobs: int = 40) -> None:
    """Engine bit-identity on every workload pattern (smaller traces — the
    reference engine is the slow side)."""
    from repro.core.jobs import WORKLOAD_PATTERNS, make_workload
    from repro.core.simulator import simulate

    for pattern in sorted(WORKLOAD_PATTERNS):
        jobs = make_workload(pattern, n_jobs, 500.0, 3)
        for strat in ("precompute", "exploratory"):
            fast = simulate(jobs, 64, strat, engine="table")
            seed = simulate(jobs, 64, strat, engine="reference")
            assert_trace_parity(fast, seed, strat,
                                f"on pattern {pattern!r}")


# Pinned churn trajectories (fault injection): 40-job mixed_maxw trace on
# the fragmented cluster under churn_4/seed 5.  The fault schedule is a
# pure PCG64 function of (cluster, seed), so these are stable across
# machines — a drift means the fault delivery or eviction path changed.
CHURN_40JOB_SHA256 = {
    "precompute":
        "50d49ed1a4e422cb14355123192cad0f53f61221ab324a4f92b77646b2aa2ef6",
    "srtf":
        "9ad59a4cace807739a8a6459c0629424271e4757bb4df95681c77ec580628ab0",
}


def _trace_sha256(res) -> str:
    payload = json.dumps(sorted(res.completion_times.items()))
    return hashlib.sha256(payload.encode()).hexdigest()


def _check_faults(n_jobs: int = 40) -> None:
    """Fault-injection gates: (a) zero-fault runs — ``faults="none"`` —
    are bit-identical to the fault-free cluster, every registered policy;
    (b) under deterministic churn both engines agree bit-for-bit and the
    pinned sha256 trajectories hold; (c) goodput is bounded to [0, 1] and
    the failure-aware policy beats blind srtf on goodput in at least one
    churn scenario (the robustness acceptance row)."""
    import dataclasses
    from benchmarks.table3_scheduler_sim import (CHURN_SCENARIOS,
                                                 FRAGMENTED)
    from repro.core import telemetry as tele
    from repro.core.faults import get_fault_model
    from repro.core.jobs import make_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate

    jobs = make_workload("mixed_maxw", n_jobs, 500.0, 3)
    nofault = dataclasses.replace(FRAGMENTED, faults="none")
    for strat in registered_policies().values():
        base = simulate(jobs, strategy=strat, cluster=FRAGMENTED)
        none = simulate(jobs, strategy=strat, cluster=nofault)
        assert_trace_parity(none, base, strat, "faults='none' no-op")
    churn = dataclasses.replace(FRAGMENTED, faults="churn_4", fault_seed=5,
                                checkpoint_interval=200.0)
    model = get_fault_model("churn_4")
    horizon = jobs[-1].arrival
    assert model.schedule(churn, 5, horizon) == model.schedule(
        churn, 5, horizon), "fault schedule is not deterministic"
    for strat in registered_policies().values():
        fast = simulate(jobs, strategy=strat, cluster=churn)
        again = simulate(jobs, strategy=strat, cluster=churn)
        assert fast.completion_times == again.completion_times, (
            f"simulate({strat}): churn trajectory not deterministic")
        seed = simulate(jobs, strategy=strat, cluster=churn,
                        engine="reference")
        assert_trace_parity(fast, seed, strat, "under churn")
        want = CHURN_40JOB_SHA256.get(strat)
        if want is not None:
            got = _trace_sha256(fast)
            assert got == want, (
                f"simulate({strat}) churn trajectory drifted: "
                f"sha256 {got} != pinned {want}")
    # goodput bounds + the failure-aware acceptance row, on the same
    # moderate trace the published churn table sweeps
    cjobs = make_workload("mixed_maxw", 114, 500.0, 0)
    wins = 0
    for name, cluster in CHURN_SCENARIOS.items():
        g = {}
        for strat in ("srtf", "recovery_aware"):
            res = simulate(cjobs, strategy=strat, cluster=cluster,
                           telemetry=tele.Telemetry())
            gp = res.telemetry.goodput
            assert gp is not None and 0.0 <= gp <= 1.0, (
                f"goodput out of bounds for {strat} on {name}: {gp!r}")
            g[strat] = gp
        if name.startswith("churn") and g["recovery_aware"] > g["srtf"]:
            wins += 1
    assert wins >= 1, ("recovery_aware failed to beat blind srtf on "
                       "goodput in any churn scenario")


def bench_simulate(results, csv) -> None:
    from repro.core.jobs import synthetic_workload
    from repro.core.simulator import simulate

    _check_simulate_parity()
    jobs = synthetic_workload(60, 500.0, 0)
    for strat in ("precompute", "fixed_8"):
        fast_s = _time(lambda: simulate(jobs, 64, strat, engine="table"),
                       min_repeats=3)
        seed_s = _time(lambda: simulate(jobs, 64, strat,
                                        engine="reference"),
                       min_repeats=1, budget_s=0.0)
        _record(results, csv, f"simulate/60jobs/{strat}", fast_s, seed_s)


def bench_1000jobs(results, csv) -> None:
    """Thousand-job traces: every registered policy on the Poisson trace,
    then precompute across every workload pattern.  No reference timing —
    the seed loop would take tens of minutes per run."""
    from repro.core.jobs import WORKLOAD_PATTERNS, make_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate

    jobs = make_workload("poisson", 1000, 250.0, 0)
    for strat in registered_policies().values():
        res = simulate(jobs, 64, strat)
        assert len(res.completion_times) == 1000, (
            f"simulate(1000 jobs, {strat}) lost jobs")
        fast_s = _time(lambda: simulate(jobs, 64, strat),
                       min_repeats=1, budget_s=2.0)
        _record(results, csv, f"simulate/1000jobs/{strat}", fast_s)
    for pattern in sorted(WORKLOAD_PATTERNS):
        if pattern == "poisson":
            continue        # covered above
        pjobs = make_workload(pattern, 1000, 250.0, 0)
        fast_s = _time(lambda: simulate(pjobs, 64, "precompute"),
                       min_repeats=1, budget_s=2.0)
        _record(results, csv, f"simulate/1000jobs/{pattern}", fast_s)
    # the placement engine on the fragmented Table-3 scenario cluster —
    # the per-event placement/defrag pass rides on top of the SoA loop
    # (the timed callable captures its result so the job-conservation
    # assertion doesn't cost an extra untimed run)
    from benchmarks.table3_scheduler_sim import FRAGMENTED
    pjobs = make_workload("mixed_maxw", 1000, 250.0, 0)
    last: dict = {}
    fast_s = _time(lambda: last.__setitem__(
        "res", simulate(pjobs, strategy="pack_precompute",
                        cluster=FRAGMENTED)),
                   min_repeats=1, budget_s=2.0)
    assert len(last["res"].completion_times) == 1000, (
        "placement trace lost jobs")
    _record(results, csv, "simulate/1000jobs/placement_frag", fast_s)


# The 10k-job floor (ISSUE 5): srtf must beat the pre-incremental-core
# baseline committed at PR 4 by >= 5x.  The baseline seconds are from the
# machine that committed that BENCH_scheduler.json; `_machine_scale`
# normalizes the floor to the current machine by timing the *reference*
# engine, which the incremental core never touches.
BASELINE_10K_S = {"srtf": 35.2, "precompute": 12.9}
SPEEDUP_FLOOR_10K = 5.0
# seed-engine 60-job precompute seconds on the baseline machine
# (us_per_call x speedup_vs_seed from the PR-4 BENCH_scheduler.json)
_BASELINE_SEED60_S = 23278e-6 * 56.485


def _machine_scale() -> float:
    """Current-machine speed relative to the 10k-baseline machine,
    measured on the frozen reference engine (>1 = this machine slower)."""
    from repro.core.jobs import synthetic_workload
    from repro.core.simulator import simulate

    jobs = synthetic_workload(60, 500.0, 0)
    # median-of-many inside a ~1 s budget, NOT best-of: the consumers of
    # this scale time *sustained* multi-second runs, so the probe must
    # read the machine's current sustained speed.  A min-based probe
    # latches the one turbo/quiet 25 ms window and then over-penalizes
    # the normalized wall by 20-30% whenever the machine is in a slower
    # phase (frequency scaling, ambient load); the median moves with the
    # phase the gated run actually experiences.  A 2-repeat probe is just
    # as bad the other way: +-15% swing from a single load spike.
    samples: list[float] = []
    t_start = time.perf_counter()
    while len(samples) < 5 or time.perf_counter() - t_start < 1.0:
        t0 = time.perf_counter()
        simulate(jobs, 64, "precompute", engine="reference")
        samples.append(time.perf_counter() - t0)
        if len(samples) >= 50:
            break
    samples.sort()
    seed_s = samples[len(samples) // 2]
    return seed_s / _BASELINE_SEED60_S


def bench_10k(results, csv, gate: bool = True) -> tuple[float, float]:
    """Gated 10k-job rows: one timed run per strategy, asserting job
    conservation and (for srtf, the ISSUE-5 floor) a >= 5x speedup over
    the committed pre-incremental-core baseline, machine-normalized via
    the reference engine.  Returns (srtf seconds, machine scale)."""
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    scale = _machine_scale()
    csv(f"simulate/10000jobs/machine_scale,0,{scale:.2f}x")
    jobs = make_workload("poisson", 10_000, 250.0, 0)
    srtf_s = 0.0
    for strat in ("precompute", "srtf"):
        last: dict = {}
        fast_s = _time(lambda: last.__setitem__(
            "res", simulate(jobs, 64, strat)),
                       min_repeats=1, budget_s=0.0)
        assert len(last["res"].completion_times) == 10_000, (
            f"simulate(10k jobs, {strat}) lost jobs")
        _record(results, csv, f"simulate/10000jobs/{strat}", fast_s)
        rss = _peak_rss_mb()
        results[f"simulate/10000jobs/{strat}"]["peak_rss_mb"] = rss
        csv(f"simulate/10000jobs/{strat}/peak_rss_mb,0,{rss:.0f}")
        speedup = BASELINE_10K_S[strat] * scale / fast_s
        csv(f"simulate/10000jobs/{strat}/speedup_vs_pr4,0,{speedup:.1f}x")
        if strat == "srtf":
            srtf_s = fast_s
            if gate:
                assert speedup >= SPEEDUP_FLOOR_10K, (
                    f"10k-job srtf regressed: {fast_s:.2f}s is only "
                    f"{speedup:.1f}x over the {BASELINE_10K_S[strat]}s "
                    f"PR-4 baseline (floor {SPEEDUP_FLOOR_10K}x, machine "
                    f"scale {scale:.2f})")
    return srtf_s, scale


# Telemetry-overhead ceiling (ISSUE 9): a telemetered 10k-job srtf run
# (counters + events into a bounded ring) may cost at most this factor
# over the zero-overhead disabled path.
TELEMETRY_OVERHEAD_CEIL = 1.3


def bench_telemetry_overhead(results, csv, gate: bool = True) -> None:
    """Gated telemetry-overhead row: time the 10k-job srtf trace with
    telemetry off and on (ring sink — the bounded-memory configuration a
    long trace would use), assert the trajectories match and the on/off
    wall ratio stays under ``TELEMETRY_OVERHEAD_CEIL``."""
    from repro.core import telemetry as tele
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    jobs = make_workload("poisson", 10_000, 250.0, 0)
    last: dict = {}
    # interleaved off/on pairs, median of the per-pair ratios: each pair
    # runs back-to-back (~2.5 s), so ambient load / thermal drift —
    # easily +-30% wall on shared runners, and slower-moving than one
    # pair — hits both sides of a pair alike and cancels out of its
    # ratio; the median then shrugs off the odd pair where a load spike
    # did land inside the window.
    # automatic GC is off during the timed segments (as timeit does), with
    # an explicit collect between them: the on-runs retire ~66k event
    # dicts each, and when this bench runs late in --check the heap also
    # carries debris from earlier lanes — so whether (and over how large a
    # heap) a gen-2 collection fires inside a segment is luck worth
    # ~0.3 s, bigger than the effect being measured.  Allocation cost
    # itself still lands on the on-side, where it belongs.
    offs: list[float] = []
    ons: list[float] = []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(7):
            gc.collect()
            t0 = time.perf_counter()
            last["off"] = simulate(jobs, 64, "srtf")
            offs.append(time.perf_counter() - t0)
            gc.collect()
            t0 = time.perf_counter()
            last["on"] = simulate(
                jobs, 64, "srtf",
                telemetry=tele.Telemetry(sink=tele.RingSink(65536)))
            ons.append(time.perf_counter() - t0)
    finally:
        if gc_was_enabled:
            gc.enable()
    off_s, on_s = min(offs), min(ons)
    assert_trace_parity(last["on"], last["off"], "srtf",
                        "10k jobs with telemetry on vs off")
    # two consistent estimators of the true ratio, gate on the smaller:
    # the pair-median is unbiased when drift is slower than a pair but
    # inflates when a load spike lands inside >=4 on-segments; min/min
    # is robust to spikes (additive noise only pushes walls up) but
    # inflates when the off- and on-minima come from different quiet
    # windows.  Ambient noise rarely inflates both at once, while a
    # genuine regression raises both — so min(median, min/min) keeps
    # the flake rate down without loosening the ceiling.
    ratios = sorted(on / off for on, off in zip(ons, offs))
    ratio = min(ratios[len(ratios) // 2], on_s / off_s)
    _record(results, csv, "simulate/10000jobs/srtf_telemetry", on_s)
    csv(f"simulate/10000jobs/srtf_telemetry/overhead,0,{ratio:.2f}x")
    if gate:
        assert ratio <= TELEMETRY_OVERHEAD_CEIL, (
            f"telemetry overhead regressed: 10k-job srtf is {ratio:.2f}x "
            f"with telemetry on ({on_s:.2f}s vs {off_s:.2f}s off; ceiling "
            f"{TELEMETRY_OVERHEAD_CEIL}x)")


# The 100k-job floor (ISSUE 8): machine-normalized wall ceiling per
# strategy.  The ISSUE target is ~10 s on the baseline (scale-1.0)
# machine; the sparse-delta core lands at ~10.5 s (precompute) /
# ~11.3 s (srtf) normalized, down from 47 / 65 s raw before it.  The
# ceilings sit ~30% above the landing numbers: raw wall swings +-10%
# run-to-run (more when the lane runs last in the full --check, against
# a heap and thermal state the earlier lanes left behind) and the
# machine-scale probe a few percent more, so a tighter bound flakes on
# timer noise while a real regression (the pre-delta core was 4-6x
# slower) still trips it by miles.
CEIL_100K_S = {"precompute": 14.0, "srtf": 15.0}


def bench_100k(results, csv, gate: bool = False,
               scale: float | None = None) -> None:
    """100k-job rows: the workload-study scale the incremental core opens
    up.  Arrival rate matches the 10k trace (same 250 s mean interarrival
    via ``make_workload``), so the backlog depth — not the per-job work —
    is what grows 10x.  Job conservation is always asserted; with
    ``gate=True`` (the ``--check-100k`` lane) the machine-normalized wall
    time must also stay under ``CEIL_100K_S`` per strategy."""
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    if gate and scale is None:
        scale = _machine_scale()
    jobs = make_workload("poisson", 100_000, 250.0, 0)
    for strat in ("precompute", "srtf"):
        last: dict = {}
        # collect before timing: in the full --check this lane runs last,
        # after the telemetry bench has churned ~1M event dicts — timing
        # against that debris-laden heap costs up to ~30% extra wall
        # (observed 10.8 s -> 13.9 s raw for srtf) purely from GC pauses
        # during the run.
        gc.collect()
        fast_s = _time(lambda: last.__setitem__(
            "res", simulate(jobs, 64, strat)),
                       min_repeats=1, budget_s=0.0)
        assert len(last["res"].completion_times) == 100_000, (
            f"simulate(100k jobs, {strat}) lost jobs")
        _record(results, csv, f"simulate/100000jobs/{strat}", fast_s)
        rss = _peak_rss_mb()
        results[f"simulate/100000jobs/{strat}"]["peak_rss_mb"] = rss
        csv(f"simulate/100000jobs/{strat}/peak_rss_mb,0,{rss:.0f}")
        if gate:
            norm = fast_s / scale
            csv(f"simulate/100000jobs/{strat}/normalized_s,0,{norm:.1f}")
            assert norm <= CEIL_100K_S[strat], (
                f"100k-job {strat} regressed: {fast_s:.2f}s raw is "
                f"{norm:.1f}s machine-normalized (ceiling "
                f"{CEIL_100K_S[strat]}s, machine scale {scale:.2f})")


def bench_1m(results, csv) -> None:
    """Non-gating 1M-job rows (``--profile-1m``): the first
    production-cluster-scale trace — arrival-rate-matched to the 10k/100k
    traces, so backlog depth grows another 10x.  Minutes of wall per
    strategy: a trend line for the trajectory note in
    ``benchmarks/README.md``, never a CI gate."""
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    jobs = make_workload("poisson", 1_000_000, 250.0, 0)
    for strat in ("precompute", "srtf"):
        last: dict = {}
        fast_s = _time(lambda: last.__setitem__(
            "res", simulate(jobs, 64, strat)),
                       min_repeats=1, budget_s=0.0)
        assert len(last["res"].completion_times) == 1_000_000, (
            f"simulate(1M jobs, {strat}) lost jobs")
        _record(results, csv, f"simulate/1000000jobs/{strat}", fast_s)
        rss = _peak_rss_mb()
        results[f"simulate/1000000jobs/{strat}"]["peak_rss_mb"] = rss
        csv(f"simulate/1000000jobs/{strat}/peak_rss_mb,0,{rss:.0f}")


def bench_table3(results, csv) -> None:
    from repro.core.simulator import TABLE3_STRATEGIES, run_table3

    # one contention level, the full strategy sweep (the paper's six plus
    # the registry extensions), growing job counts; the reference engine
    # is only timed where it stays under a few seconds
    n_strats = len(TABLE3_STRATEGIES)
    for n_jobs, time_seed in ((20, True), (60, True), (120, False),
                              (206, False)):
        contention = {"sweep": (500.0, n_jobs)}
        fast_s = _time(lambda: run_table3(seed=0, contention=contention),
                       min_repeats=1, budget_s=1.0)
        seed_s = None
        if time_seed:
            seed_s = _time(lambda: run_table3(seed=0, contention=contention,
                                              engine="reference"),
                           min_repeats=1, budget_s=0.0)
        _record(results, csv, f"table3/sweep{n_strats}/n={n_jobs}", fast_s,
                seed_s)


# Wall-clock budget for the blocking `--check` lane.  The 10k-job gate
# joins the lane only while the parity checks leave room for it — on a
# machine (or under a regression) where they already blow the budget,
# the gate defers to the non-blocking full-suite lane, which forces it
# with ``--check-10k``.
CHECK_BUDGET_S = 120.0


def check(csv=print, gate_10k: bool | None = None,
          gate_100k: bool | None = None) -> None:
    """Parity-only mode for CI: every correctness assertion the timed
    benchmark makes, none of the timing loops, no JSON write.

    ``gate_10k=None`` runs the 10k-job floor only if the parity checks
    finished inside ``CHECK_BUDGET_S`` (keeping the blocking lane under
    its budget on slow machines); True forces it, False skips it.
    ``gate_100k`` works the same way against the cumulative wall clock —
    on a fast runner the blocking lane covers the 100k floor too, on a
    slow one it defers to the non-blocking lane's ``--check-100k``.
    """
    t0 = time.perf_counter()
    for n_jobs in (10, 30, 60):
        _check_solvers(n_jobs)
    csv("check/solver_parity,0,ok")
    _check_simulate_parity()
    csv("check/simulate_60jobs_parity,0,ok")
    _check_pattern_parity()
    csv("check/pattern_parity,0,ok")
    _check_cluster_parity()
    csv("check/cluster_parity,0,ok")
    _check_placement_parity()
    csv("check/placement_parity,0,ok")
    _check_telemetry()
    csv("check/telemetry_parity,0,ok")
    _check_faults()
    csv("check/fault_parity,0,ok")
    from repro.core.jobs import make_workload
    from repro.core.scheduler import registered_policies
    from repro.core.simulator import simulate
    # every registered policy — not just the timed subset — must finish a
    # 1000-job trace (catches policies that stall or lose jobs only at
    # high concurrency)
    jobs = make_workload("poisson", 1000, 250.0, 0)
    for strat in registered_policies().values():
        res = simulate(jobs, 64, strat)
        assert len(res.completion_times) == 1000, strat
    csv("check/simulate_1000jobs_completes,0,ok")
    elapsed = time.perf_counter() - t0
    if gate_10k is None:
        gate_10k = elapsed < CHECK_BUDGET_S
        if not gate_10k:
            csv(f"check/10k_gate,0,deferred (parity took {elapsed:.0f}s "
                f">= budget {CHECK_BUDGET_S:.0f}s; full lane forces it)")
    if gate_10k:
        bench_10k({}, csv)
        csv("check/simulate_10000jobs_floor,0,ok")
        bench_telemetry_overhead({}, csv)
        csv("check/telemetry_overhead,0,ok")
    elapsed = time.perf_counter() - t0
    if gate_100k is None:
        gate_100k = gate_10k and elapsed < CHECK_BUDGET_S
        if not gate_100k:
            csv(f"check/100k_gate,0,deferred (wall at {elapsed:.0f}s "
                f">= budget {CHECK_BUDGET_S:.0f}s; full lane forces it)")
    if gate_100k:
        # scale=None -> bench_100k re-probes machine speed at the lane
        # itself: the bench_10k probe above is minutes stale by now, and
        # on a machine that heats up over the run a stale (faster) scale
        # over-penalizes the normalized 100k wall by ~10%.
        bench_100k({}, csv, gate=True, scale=None)
        csv("check/simulate_100000jobs_floor,0,ok")
    csv(f"check/wall_us,{(time.perf_counter() - t0) * 1e6:.0f},done")


def main(csv=print, write_json: bool = True,
         profile_100k: bool = False, profile_1m: bool = False) -> dict:
    results: dict[str, dict] = {}
    bench_solvers(results, csv)
    bench_simulate(results, csv)
    bench_1000jobs(results, csv)
    _, scale = bench_10k(results, csv)
    bench_telemetry_overhead(results, csv)
    if profile_100k:
        bench_100k(results, csv)
    if profile_1m:
        bench_1m(results, csv)
    results["_host"] = _host_metadata(scale)
    bench_table3(results, csv)
    sim = results["simulate/60jobs/precompute"]["speedup_vs_seed"]
    csv(f"scheduler/simulate_speedup_vs_seed,0,{sim:.1f}x")
    assert sim >= 20.0, (
        f"simulate(60 jobs) speedup regressed below 20x: {sim:.1f}x")
    if write_json:
        with open(JSON_PATH, "w") as fh:
            json.dump(results, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return results


if __name__ == "__main__":
    import sys
    argv = sys.argv[1:]
    if "--check-100k" in argv:
        check(gate_10k=True, gate_100k=True)
    elif "--check-10k" in argv:
        check(gate_10k=True)
    elif "--check" in argv:
        check()
    else:
        main(profile_100k="--profile-100k" in argv,
             profile_1m="--profile-1m" in argv)
