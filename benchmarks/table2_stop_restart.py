"""Table 2 — elastic stop/restart with LR rescale (paper §5-6).

Runs a scaled-down version of the paper's experiment end-to-end on this
host: baseline fixed-w training vs checkpoint at step k -> restart at 2w
with eq. (7) LR rescale.  Verifies (a) convergence continues, (b) measured
stop+restart cost is a tiny fraction of job time, (c) projected wall-time
saving at the paper's own Table-2 speeds.
"""
from __future__ import annotations

import tempfile

import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs.resnet110 import ResNetConfig
from repro.core.elastic import ElasticTrainer
from repro.core.jobs import JobSpec
from repro.data.synthetic import CifarLike
from repro.models.resnet import ResNetModel
from repro.optim.optimizers import sgd

PAPER_T2 = {  # (w_init, stop_step, w_new) -> total minutes
    (4, None, None): 126.0, (8, None, None): 84.0,
    (4, 5000, 8): 104.0, (4, 10000, 8): 113.0,
}


def run(total_steps: int = 60, stop_at: int = 20, depth: int = 8):
    cfg = ResNetConfig(name="resnet-bench", depth=depth, width=8)
    data = CifarLike(size=1024, seed=0)
    out = {}

    def trainer(d):
        return ElasticTrainer(ResNetModel(cfg), sgd(), data,
                              CheckpointStore(d), base_lr_1w=0.02,
                              m_per_worker=16, dataset_size=1024)

    # baseline: fixed w=4 the whole way
    with tempfile.TemporaryDirectory() as d:
        tr = trainer(d)
        r = tr.train_segment(w=4, n_steps=total_steps, resume=False,
                             log_every=5)
        out["fixed4"] = {"final_loss": r.losses[-1][2], "epochs": r.epochs,
                         "steps": total_steps}

    # elastic: w=4, stop at `stop_at`, restart at w=8 (LR doubles, eq. 7)
    with tempfile.TemporaryDirectory() as d:
        tr = trainer(d)
        r1 = tr.train_segment(w=4, n_steps=stop_at, resume=False,
                              log_every=5)
        # same number of *examples* afterwards: half the steps at 2x batch
        r2 = tr.train_segment(w=8, n_steps=(total_steps - stop_at) // 2,
                              resume=True, log_every=5)
        out["elastic4to8"] = {
            "final_loss": r2.losses[-1][2], "epochs": r2.epochs,
            "steps": stop_at + (total_steps - stop_at) // 2,
            "stop_restart_s": r1.save_seconds + r2.restore_seconds,
        }

    # projected wall-time saving at the paper's measured Table-2 speeds
    job = JobSpec(0, 0.0, 160.0)   # table2-calibrated f(w)
    t_fixed4 = job.time_for(160.0, 4) / 60.0
    stop_epochs = 51.0             # paper's 5k-step stop point
    t_elastic = (job.time_for(stop_epochs, 4)
                 + 10.0 + job.time_for(160.0 - stop_epochs, 8)) / 60.0
    out["projected"] = {
        "fixed4_min": t_fixed4, "elastic_min": t_elastic,
        "saving_pct": 100.0 * (1 - t_elastic / t_fixed4),
        "paper_saving_pct": 100.0 * (1 - 104.0 / 126.0),
    }
    return out


def main(csv=print):
    out = run()
    e, f = out["elastic4to8"], out["fixed4"]
    csv(f"table2/fixed4_final_loss,0,{f['final_loss']:.4f}")
    csv(f"table2/elastic_final_loss,0,{e['final_loss']:.4f}")
    csv(f"table2/stop_restart_s,{e['stop_restart_s']*1e6:.0f},"
        f"epochs={e['epochs']:.2f}")
    p = out["projected"]
    csv(f"table2/projected_saving_pct,0,ours={p['saving_pct']:.1f};"
        f"paper={p['paper_saving_pct']:.1f}")
    # convergence must survive the resize
    assert e["final_loss"] < f["final_loss"] + 0.5
    return out


if __name__ == "__main__":
    main()
