"""Checkpoint store: npz pytree snapshots with a JSON manifest.

Elasticity is the point (paper §6): params and optimizer state are
data-parallel-replicated, so a checkpoint written at w workers restores
bit-identically at any w' — the restart only changes the mesh and the LR
(eq. 7).  Save/restore round-trip times are measured by
benchmarks/table2_stop_restart.py.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.npz")

    def _meta_path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.json")

    def save(self, step: int, state: dict, meta: dict | None = None
             ) -> float:
        """Write a checkpoint; returns wall seconds spent.

        Both the array file and the manifest sidecar go through a
        tmp-file + ``os.replace`` dance, so a crash mid-write leaves
        either the previous snapshot or a stray tmp file — never a
        half-written ``ckpt_*`` that a later restore would trust.
        """
        t0 = time.perf_counter()
        flat = _flatten(state)
        tmp = os.path.join(self.dir, f".tmp_ckpt_{step:010d}.npz")
        np.savez(tmp[:-4], **flat)  # np.savez appends .npz itself
        os.replace(tmp, self._path(step))
        manifest = {"step": step, "meta": meta or {},
                    "time": time.time()}
        mtmp = self._meta_path(step) + ".tmp"
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, self._meta_path(step))
        return time.perf_counter() - t0

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("ckpt_") and fn.endswith(".npz"):
                try:
                    out.append(int(fn[5:-4]))
                except ValueError:  # stray/foreign file, not a snapshot
                    continue
        return sorted(out)

    def _load_arrays(self, step: int) -> dict[str, np.ndarray]:
        with np.load(self._path(step)) as z:
            return {k: z[k] for k in z.files}

    def _load_meta(self, step: int) -> dict:
        """Manifest meta, or {} when the sidecar is missing/corrupt —
        the arrays are the checkpoint; the sidecar is advisory."""
        try:
            with open(self._meta_path(step)) as f:
                return json.load(f)["meta"]
        except (OSError, ValueError, KeyError):
            return {}

    def latest_step(self) -> int | None:
        """Newest step whose array file is readable; snapshots truncated
        by a crash mid-write (pre-atomic-rename layouts, torn disks) are
        skipped rather than returned as restore targets."""
        for step in reversed(self.steps()):
            try:
                with np.load(self._path(step)) as z:
                    len(z.files)
                return step
            except Exception:
                continue
        return None

    def restore(self, template, step: int | None = None
                ) -> tuple[dict, dict, float]:
        """-> (state, meta, seconds).

        With ``step=None`` the newest *readable* snapshot wins: a
        corrupt/truncated ``.npz`` is skipped and the next older one is
        tried, so a torn write costs one checkpoint interval of
        progress, not the whole run.  An explicit ``step`` is trusted —
        corruption there raises.
        """
        t0 = time.perf_counter()
        if step is not None:
            flat = self._load_arrays(step)
            state = _unflatten(template, flat)
            return state, self._load_meta(step), time.perf_counter() - t0
        candidates = self.steps()
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        for s in reversed(candidates):
            try:
                flat = self._load_arrays(s)
            except Exception:
                continue  # torn snapshot: fall back to the next older
            state = _unflatten(template, flat)
            return state, self._load_meta(s), time.perf_counter() - t0
        raise FileNotFoundError(f"no readable checkpoint in {self.dir}")
