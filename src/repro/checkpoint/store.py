"""Checkpoint store: npz pytree snapshots with a JSON manifest.

Elasticity is the point (paper §6): params and optimizer state are
data-parallel-replicated, so a checkpoint written at w workers restores
bit-identically at any w' — the restart only changes the mesh and the LR
(eq. 7).  Save/restore round-trip times are measured by
benchmarks/table2_stop_restart.py.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.npz")

    def save(self, step: int, state: dict, meta: dict | None = None
             ) -> float:
        """Write a checkpoint; returns wall seconds spent."""
        t0 = time.perf_counter()
        flat = _flatten(state)
        tmp = self._path(step) + ".tmp.npz"  # np.savez appends .npz itself
        np.savez(tmp[:-4], **flat)
        os.replace(tmp, self._path(step))
        manifest = {"step": step, "meta": meta or {},
                    "time": time.time()}
        with open(os.path.join(self.dir, f"ckpt_{step:010d}.json"), "w") as f:
            json.dump(manifest, f)
        return time.perf_counter() - t0

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.dir):
            if fn.startswith("ckpt_") and fn.endswith(".npz"):
                out.append(int(fn[5:-4]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: int | None = None
                ) -> tuple[dict, dict, float]:
        """-> (state, meta, seconds)."""
        t0 = time.perf_counter()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with np.load(self._path(step)) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten(template, flat)
        with open(os.path.join(self.dir, f"ckpt_{step:010d}.json")) as f:
            manifest = json.load(f)
        return state, manifest["meta"], time.perf_counter() - t0
