"""Step builders: train_step / prefill / decode_step closures over a model,
optimizer and Sharder — the functions the launcher jits with in/out
shardings and the dry-run lowers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Sharder, NO_SHARD
from repro.optim.optimizers import Optimizer


def make_train_step(model, optimizer: Optimizer, sh: Sharder = NO_SHARD,
                    grad_exchange: str | None = None, axis: str = "data",
                    microbatches: int = 1):
    """(state {params, opt}, batch, lr) -> (state, loss).

    grad_exchange: None => implicit GSPMD reduction (production path);
    "ring"/"doubling_halving" are only valid inside shard_map (the
    paper-faithful explicit path, see examples/explicit_allreduce.py).

    microbatches > 1: gradient accumulation — the global batch is split
    into k sequential microbatches inside a lax.scan, bounding live
    activations to one microbatch (the memory-roofline knob for big-model
    training; EXPERIMENTS.md §Perf).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: model.loss(p, batch, sh))(params)

    def train_step(state, batch, lr):
        params = state["params"]
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            k = microbatches
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mbatch):
                acc, lsum = carry
                loss_i, g_i = grads_of(params, mbatch)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, g_i)
                return (acc, lsum + loss_i), None

            (grads, lsum), _ = jax.lax.scan(body, (zeros, 0.0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = lsum / k
        if grad_exchange:
            from repro.collectives.xla import exchange_tree
            grads = exchange_tree(grads, axis, grad_exchange)
            n = jax.lax.axis_size(axis)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], lr)
        return {"params": new_params, "opt": new_opt}, loss

    return train_step


def make_prefill(model, sh: Sharder = NO_SHARD, window: int | None = None):
    def prefill(params, batch):
        return model.prefill(params, batch, sh, window=window)

    return prefill


def make_decode_step(model, sh: Sharder = NO_SHARD,
                     window: int | None = None):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch, sh, window=window)

    return decode_step


def init_train_state(model, optimizer: Optimizer, key=None) -> dict:
    params = model.init(key if key is not None else jax.random.PRNGKey(0))
    return {"params": params, "opt": optimizer.init(params)}


def train_state_specs(model, optimizer: Optimizer) -> dict:
    """TensorSpec tree for the full train state (params + optimizer state),
    used by the dry-run to build shardings/abstract values without
    allocating.  Optimizer state mirrors param specs; scalar counters are
    plain TensorSpecs with no axes."""
    from repro.models.spec import TensorSpec as TS

    pspecs = model.param_specs()
    if optimizer.name == "sgd":
        opt = {"mu": pspecs}
    elif optimizer.name == "adamw":
        opt = {"m": pspecs, "v": pspecs, "t": TS((), (), dtype=jnp.int32,
                                                 init="zeros")}
    else:
        raise ValueError(optimizer.name)
    return {"params": pspecs, "opt": opt}
