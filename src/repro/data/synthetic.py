"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step), so elastic restarts resume
the stream exactly, and different worker counts draw from the same logical
dataset order (batch b at global batch size B covers example indices
[b*B, (b+1)*B) of the infinite stream).
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Zipf-ish synthetic LM tokens with a learnable structure: token t+1 is
    a noisy function of token t, so models actually reduce loss."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 noise: float = 0.1):
        self.vocab = vocab_size
        self.seq = seq_len
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab_size)  # hidden transition table

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch_size, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        flip = rng.random((batch_size, self.seq)) < self.noise
        rand = rng.integers(0, self.vocab, (batch_size, self.seq))
        for t in range(self.seq):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(flip[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class CifarLike:
    """Synthetic CIFAR-10-like dataset: ``size`` images whose class signal
    is a fixed per-class template + noise (linearly separable-ish, so the
    ResNet's loss curve has the O(1/k) shape eq. (1) models)."""

    def __init__(self, size: int = 50_000, image: int = 32, classes: int = 10,
                 seed: int = 0):
        self.size = size
        self.image = image
        self.classes = classes
        rng = np.random.default_rng(seed)
        self.templates = rng.normal(size=(classes, image, image, 3)
                                    ).astype(np.float32)
        self.labels_all = rng.integers(0, classes, size).astype(np.int32)
        self.seed = seed

    def batch(self, step: int, batch_size: int) -> dict:
        idx = (np.arange(batch_size) + step * batch_size) % self.size
        labels = self.labels_all[idx]
        rng = np.random.default_rng((self.seed, step, 7))
        noise = rng.normal(scale=1.0, size=(batch_size, self.image,
                                            self.image, 3)).astype(np.float32)
        images = 0.6 * self.templates[labels] + noise
        return {"images": images, "labels": labels}

    def steps_per_epoch(self, batch_size: int) -> float:
        return self.size / batch_size
