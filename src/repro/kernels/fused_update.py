"""Fused flat-buffer momentum-SGD update — Pallas TPU kernel.

The Horovod "fusion buffer" analogue on TPU: after gradient exchange, the
packed 1-D gradient buffer is consumed in one VMEM pass that applies weight
decay, updates momentum, and writes new params — 3 reads + 2 writes per
element instead of the ~3x traffic of unfused elementwise HLOs.  Also used
on elastic restarts where the LR just changed (eq. 7): lr rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(lr_ref, p_ref, g_ref, mu_ref, p_out, mu_out, *,
            momentum: float, weight_decay: float, nesterov: bool):
    lr = lr_ref[0]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) + weight_decay * p
    mu = mu_ref[...].astype(jnp.float32)
    mu_new = momentum * mu + g
    step = (g + momentum * mu_new) if nesterov else mu_new
    p_out[...] = (p - lr * step).astype(p_out.dtype)
    mu_out[...] = mu_new.astype(mu_out.dtype)


def fused_sgd_update(params_flat, grads_flat, mu_flat, lr, *,
                     momentum: float = 0.9, weight_decay: float = 1e-4,
                     nesterov: bool = False, block: int = 65536,
                     interpret: bool = False):
    """params/grads/mu: 1-D f32 buffers of equal length; lr: scalar.

    Returns (new_params, new_mu).
    """
    n = params_flat.shape[0]
    block = min(block, n)
    n_blocks = -(-n // block)
    pad = n_blocks * block - n

    def pad1(x):
        return jnp.pad(x, ((0, pad),)) if pad else x

    p, g, mu = pad1(params_flat), pad1(grads_flat), pad1(mu_flat)
    lr_arr = jnp.asarray([lr], jnp.float32)

    kern = functools.partial(_kernel, momentum=momentum,
                             weight_decay=weight_decay, nesterov=nesterov)
    new_p, new_mu = pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # lr scalar
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, params_flat.dtype),
            jax.ShapeDtypeStruct(mu.shape, mu_flat.dtype),
        ],
        interpret=interpret,
    )(lr_arr, p, g, mu)
    if pad:
        new_p, new_mu = new_p[:n], new_mu[:n]
    return new_p, new_mu
