"""Jitted public wrappers around the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (the body
runs in Python/XLA-CPU for correctness validation); on a TPU runtime
``interpret=False`` compiles the real Mosaic kernel.  The default follows
the backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import fused_update as _fu
from repro.kernels import rmsnorm as _rms
from repro.kernels import swa_attention as _swa


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def swa_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool | None = None):
    """Sliding-window flash attention. q/k/v: [BH, S, D]."""
    interp = _default_interpret() if interpret is None else interpret
    return _swa.swa_attention(q, k, v, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interp)


@partial(jax.jit, static_argnames=("momentum", "weight_decay", "nesterov",
                                   "block", "interpret"))
def fused_sgd_update(params_flat, grads_flat, mu_flat, lr, *,
                     momentum: float = 0.9, weight_decay: float = 1e-4,
                     nesterov: bool = False, block: int = 65536,
                     interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _fu.fused_sgd_update(params_flat, grads_flat, mu_flat, lr,
                                momentum=momentum, weight_decay=weight_decay,
                                nesterov=nesterov, block=block,
                                interpret=interp)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    """Fused RMSNorm (gain = 1 + w). x: [..., D]; w: [D]."""
    interp = _default_interpret() if interpret is None else interpret
    return _rms.rmsnorm(x, w, eps=eps, block_rows=block_rows,
                        interpret=interp)
