"""Sliding-window flash attention Pallas TPU kernel.

TPU adaptation notes (DESIGN.md §3): the GPU flash-attention tiling maps to
a (batch*heads, q_blocks, k_blocks) grid with the k dimension innermost so
the online-softmax running state (m, l, acc) lives in VMEM scratch across k
steps.  Blocks are 128-aligned for the MXU.  Sliding-window + causal
masking is positional: k blocks entirely outside [q_pos - window, q_pos]
are skipped with ``pl.when`` (no MXU work; see EXPERIMENTS.md §Perf for the
DMA-skip refinement).

Layout: q, k, v are [BH, S, D] (batch*heads flattened, KV already
GQA-repeated).  f32 accumulation, bf16/f32 inputs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, seq_len: int,
            causal: bool, window: int | None, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * block_q
    k_lo = ki * block_k
    # block-level skip: causal => k_lo <= q_hi; window => k_hi > q_lo - W
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + block_q - 1
    if window is not None:
        live &= (k_lo + block_k - 1) > (q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [BQ, D]
        k = k_ref[0].astype(jnp.float32)              # [BK, D]
        v = v_ref[0].astype(jnp.float32)              # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # [BQ]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def swa_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = False):
    """q, k, v: [BH, S, D] -> [BH, S, D]."""
    bh, s, d = q.shape
    assert k.shape == v.shape == (bh, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_q = -(-s // block_q)
    n_k = -(-s // block_k)
    pad = n_q * block_q - s
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v

    grid = (bh, n_q, n_k)
    kern = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), block_q=block_q, block_k=block_k,
        seq_len=s, causal=causal, window=window, n_k=n_k)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m: running max
            pltpu.VMEM((block_q,), jnp.float32),      # l: running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # acc: running output
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s] if pad else out
