"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def swa_attention_ref(q, k, v, *, causal: bool = True,
                      window: int | None = None):
    """q, k, v: [BH, S, D] -> [BH, S, D]; f32 math throughout."""
    bh, s, d = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqd,bkd->bqk", qf, kf) / math.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)


def fused_sgd_update_ref(params_flat, grads_flat, mu_flat, lr, *,
                         momentum: float = 0.9, weight_decay: float = 1e-4,
                         nesterov: bool = False):
    p = params_flat.astype(jnp.float32)
    g = grads_flat.astype(jnp.float32) + weight_decay * p
    mu_new = momentum * mu_flat.astype(jnp.float32) + g
    step = (g + momentum * mu_new) if nesterov else mu_new
    return ((p - lr * step).astype(params_flat.dtype),
            mu_new.astype(mu_flat.dtype))


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
