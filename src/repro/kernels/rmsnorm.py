"""Fused RMSNorm Pallas TPU kernel.

Per-layer hot-spot: one VMEM pass computes the mean-square, normalizes and
applies the (1 + scale) gain — versus three HBM round-trips unfused.
Rows tile along the grid; the feature dim stays resident (d_model <= a few
K fits VMEM easily at 128-aligned tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # [rows, d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    w = w_ref[...].astype(jnp.float32)              # [d]
    o_ref[...] = (y * (1.0 + w)[None, :]).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: [..., D]; w: [D] (gain is 1 + w, matching repro.models.layers)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    n_blocks = -(-n // block_rows)
    pad = n_blocks * block_rows - n
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
