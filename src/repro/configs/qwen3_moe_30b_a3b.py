"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained expert d_ff=768.
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab_size=151936,  # d_ff = per-expert (moe_intermediate_size)
    n_experts=128, top_k=8, rope_theta=1_000_000.0,
)


def smoke_config():
    return reduced(CONFIG)
