"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=10752, vocab_size=100352,  # d_ff per expert
    n_experts=16, top_k=4, rope_theta=500_000.0,
)


def smoke_config():
    return reduced(CONFIG)
