"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1). [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    source="arXiv:2403.08295 (Gemma 2B)",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
    d_ff=16384, vocab_size=256000,
    activation="geglu", tie_embeddings=True,
)


def smoke_config():
    return reduced(CONFIG, d_head=32)
