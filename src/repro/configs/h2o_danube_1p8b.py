"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    source="arXiv:2401.16818 (H2O-Danube 1.8B)",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_head=80,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=10_000.0, activation="silu",
)


def smoke_config():
    return reduced(CONFIG)
