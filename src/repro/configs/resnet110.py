"""ResNet-110 / CIFAR-10 — the paper's own experimental workload (§5).
Depth 6n+2 with n=18, non-bottleneck blocks. [He et al. 2016; paper §5]"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet110"
    depth: int = 110                  # 6n+2, n=18
    num_classes: int = 10
    width: int = 16                   # stage widths 16/32/64
    image_size: int = 32
    source: str = "paper §5; arXiv:1603.05027"

    @property
    def n(self) -> int:
        assert (self.depth - 2) % 6 == 0
        return (self.depth - 2) // 6


CONFIG = ResNetConfig()


def smoke_config():
    return ResNetConfig(name="resnet8-smoke", depth=8, width=8)
