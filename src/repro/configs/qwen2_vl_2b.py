"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision stub). [arXiv:2409.12191]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    source="arXiv:2409.12191 (Qwen2-VL), 2B backbone",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0, mrope=True, activation="silu",
    frontend="vision", n_frontend_tokens=256,  # stub: precomputed patch embeds
)


def smoke_config():
    return reduced(CONFIG)
