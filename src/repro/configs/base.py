"""Architecture configuration system.

One frozen dataclass covers every assigned family (dense / moe / ssm /
hybrid / vlm / audio).  Each ``configs/<id>.py`` exports ``CONFIG`` with the
exact published numbers (source cited) and ``smoke_config()`` returning the
reduced same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    source: str  # citation for the numbers

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention details
    # pad_heads_to: shard-friendly padded Q-head count (> n_heads). Extra
    # heads are hard-masked to zero output, so the model is mathematically
    # identical — this exists purely so 40 or 12 heads can shard on a
    # 16-way model axis (EXPERIMENTS.md §Perf, beyond-paper optimization).
    pad_heads_to: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False                  # Qwen2-VL multimodal 3D RoPE
    sliding_window: int | None = None    # native SWA (h2o-danube)
    # long_500k fallback window for otherwise full-attention archs:
    long_context_window: int = 4096

    # MLP
    activation: str = "silu"             # silu | geglu | gelu
    norm: str = "rmsnorm"                # rmsnorm | layernorm

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1                   # apply MoE every k-th layer

    # SSM (Mamba2 / Jamba mamba layers)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0                  # hybrid: 1 attention layer per block

    # encoder/decoder + modality frontend (STUB per assignment)
    encoder_layers: int = 0              # >0 => encoder-decoder (whisper)
    frontend: str | None = None          # "audio" | "vision" | None
    n_frontend_tokens: int = 0           # stub embedding count (frames/patches)

    tie_embeddings: bool = False

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings included)."""
        from repro.models.registry import build_model
        from repro.models import spec as pspec
        return pspec.n_params(build_model(self).param_specs())

    def active_param_count(self) -> int:
        """Params active per token (MoE counts top_k of n_experts)."""
        from repro.models.registry import build_model
        from repro.models import spec as pspec
        model = build_model(self)
        total = pspec.n_params(model.param_specs())
        if not self.is_moe:
            return total
        # subtract inactive expert weights
        expert = pspec.n_params(model.expert_param_specs())
        inactive = expert * (1 - self.top_k / self.n_experts)
        return int(total - inactive)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers etc.)."""
    small: dict = dict(
        n_layers=2, d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
    )
    if cfg.is_moe:
        small.update(n_experts=min(cfg.n_experts, 4),
                     top_k=min(cfg.top_k, 2))
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=min(cfg.ssm_state, 16), ssm_headdim=16,
                     ssm_chunk=16)
    if cfg.attn_every:
        small.update(attn_every=2, n_layers=4)
    if cfg.encoder_layers:
        small.update(encoder_layers=2)
    if cfg.n_frontend_tokens:
        small.update(n_frontend_tokens=16)
    if cfg.sliding_window:
        small.update(sliding_window=32)
    small["long_context_window"] = 64
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
