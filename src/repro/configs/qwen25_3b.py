"""qwen2.5-3b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B family card]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    source="hf:Qwen/Qwen2.5-0.5B (Qwen2.5 family card, 3B row)",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_head=128,
    d_ff=11008, vocab_size=151936,
    qkv_bias=True, rope_theta=1_000_000.0, activation="silu",
)


def smoke_config():
    return reduced(CONFIG)
