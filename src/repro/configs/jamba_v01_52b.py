"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    source="arXiv:2403.19887 (Jamba v0.1)",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_every=2, rope_theta=0.0,  # no PE (Mamba provides position)
    ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=256,
    attn_every=8,  # one attention layer per 8-layer block (1:7)
)


def smoke_config():
    return reduced(CONFIG)
