"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B family card]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    source="hf:Qwen/Qwen2.5-0.5B (Qwen2.5 family card, 14B row)",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=13824, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0, activation="silu",
)


def smoke_config():
    return reduced(CONFIG)
