"""Config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "qwen2.5-3b": "repro.configs.qwen25_3b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1p8b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "gemma-2b": "repro.configs.gemma_2b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "whisper-base": "repro.configs.whisper_base",
    "qwen2.5-14b": "repro.configs.qwen25_14b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_IDS)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str):
    return importlib.import_module(_ARCH_MODULES[arch_id]).smoke_config()
