"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    source="arXiv:2405.21060 (Mamba-2 780m)",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=256,
)


def smoke_config():
    return reduced(CONFIG)
