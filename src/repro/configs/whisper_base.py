"""whisper-base [audio] — encoder-decoder transformer backbone; the
mel-spectrogram + conv frontend is a STUB per assignment (precomputed frame
embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    source="arXiv:2212.04356 (Whisper base)",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
    d_ff=2048, vocab_size=51865,
    activation="gelu", norm="layernorm", rope_theta=0.0,  # sinusoidal pos
    encoder_layers=6, frontend="audio", n_frontend_tokens=1500,
)


def smoke_config():
    return reduced(CONFIG, n_kv_heads=4)
