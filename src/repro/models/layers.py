"""Shared neural-net layers (pure JAX, bf16 compute / f32 params).

Attention is *query-chunked* (flash-style at the XLA level): scores are only
ever materialized for one query block at a time, so 32k prefill never builds
an S x S tensor.  On real TPUs the Pallas kernel in
``repro.kernels.swa_attention`` replaces the inner block; the XLA path here
is the portable reference and the one the dry-run lowers.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding.rules import ShardingRules, default_rules

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Unroll mode: XLA's cost_analysis counts while-loop bodies ONCE, so the
# dry-run lowers small *unrolled* depth variants to measure true per-layer
# flops/bytes/collective deltas (launch/dryrun.py).  Production path always
# scans (compile-time hygiene).
_UNROLL = False


@contextlib.contextmanager
def unroll_mode(on: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = on
    try:
        yield
    finally:
        _UNROLL = prev


def unrolled() -> bool:
    return _UNROLL


def remat_policy():
    """Activation-checkpoint policy for the layer scan.

    REPRO_REMAT_POLICY=full (default): save nothing, recompute everything —
    minimal memory.  =dots: keep matmul outputs (no recompute of the MXU
    work) — the compute-vs-memory knob exercised in EXPERIMENTS.md §Perf.
    """
    name = os.environ.get("REPRO_REMAT_POLICY", "full")
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def scan_layers(body, carry, xs, checkpoint_body: bool = True):
    """lax.scan over stacked layer params, or a python loop in unroll mode.

    Returns (carry, ys) where ys leaves are stacked along axis 0 (or None).
    """
    body_fn = (jax.checkpoint(body, policy=remat_policy())
               if checkpoint_body else body)
    if not _UNROLL:
        return jax.lax.scan(body_fn, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body_fn(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys


class Sharder:
    """Threads ``with_sharding_constraint`` hints through model code.

    Outside a mesh (CPU smoke tests) it is a no-op, so the same model code
    serves 1-device tests and 512-device dry-runs.
    """

    def __init__(self, mesh: Mesh | None = None,
                 rules: ShardingRules | None = None):
        self.mesh = mesh
        self.rules = rules or default_rules()

    def __call__(self, x, *axes):
        if self.mesh is None:
            return x
        spec = self.rules.spec_for(axes, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


NO_SHARD = Sharder()


# ---------------------------------------------------------------- norms ----
def rmsnorm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(cfg, x, p, prefix=""):
    if cfg.norm == "layernorm":
        return layernorm(x, p[prefix + "scale"], p[prefix + "bias"])
    return rmsnorm(x, p[prefix + "scale"])


# ----------------------------------------------------------------- rope ----
def rope_freqs(d_half: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_half, dtype=np.float32) / d_half))


def apply_rope(x, positions, theta: float, sections: tuple[int, ...] | None = None):
    """Rotate ``x [..., S, H, D]`` by ``positions``.

    positions: ``[B, S]`` int for standard RoPE, or ``[B, S, 3]`` for M-RoPE
    with ``sections`` (t, h, w) splitting the half-dim (Qwen2-VL style).
    """
    d = x.shape[-1]
    d_half = d // 2
    freqs = jnp.asarray(rope_freqs(d_half, theta))           # [d_half]
    if sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,d_half]
    else:
        assert positions.ndim == 3 and sum(sections) == d_half
        parts, off = [], 0
        for i, sec in enumerate(sections):
            parts.append(positions[..., i, None].astype(jnp.float32)
                         * freqs[off:off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)                 # [B,S,d_half]
    cos = jnp.cos(ang)[:, :, None, :]                         # [B,S,1,d_half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :d_half], x[..., d_half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: int | None = None, q_chunk: int = 1024,
                      q_offset: int = 0):
    """softmax(QK^T/sqrt(d)) V without materializing [S, S].

    q: [B, Sq, H, D]; k, v: [B, Sk, H, D] (KV already GQA-repeated).
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``window``: sliding-window size (key j visible to query i iff
    i - window < j <= i).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    if _UNROLL:
        # cost-measurement mode (dry-run depth variants): chunking does not
        # change flop/byte totals, so use one full-width chunk — the
        # unrolled-chunk HLO otherwise makes XLA's compile time explode.
        q_chunk = sq
    q_chunk = min(q_chunk, sq)
    n_chunks = -(-sq // q_chunk)
    pad = n_chunks * q_chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(sk)

    @jax.checkpoint  # recompute scores in backward: never store [S, S]
    def one_chunk(ci, qc):
        # qc: [B, Qc, H, D]
        qpos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((q_chunk, sk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    if n_chunks == 1:
        out = one_chunk(0, qs[0])
    else:
        if _UNROLL:
            out = jnp.stack([one_chunk(i, qs[i]) for i in range(n_chunks)])
        else:
            out = jax.lax.map(lambda args: one_chunk(args[0], args[1]),
                              (jnp.arange(n_chunks), qs))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * q_chunk, h, d)
        out = out[:, :sq] if pad else out
    return out


def decode_attention(q, k_cache, v_cache, pos, *, window: int | None = None,
                     repeated: bool = False):
    """One-token attention against a cache.

    q: [B, 1, H, D]; caches: [B, S, Hkv, D] (GQA-repeated already iff
    ``repeated``); pos: [B] int32 — number of valid tokens already in the
    cache (the new token occupies slot ``pos``).
    """
    b, s, hkv, d = k_cache.shape
    h = q.shape[2]
    if repeated:
        k, v = k_cache, v_cache
    else:
        k = repeat_kv(k_cache, h // hkv)
        v = repeat_kv(v_cache, h // hkv)
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale  # [B,H,1,S]
    kpos = jnp.arange(s)[None, :]                        # [1,S]
    valid = kpos <= pos[:, None]
    if window is not None:
        valid &= kpos > (pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------- mlps -----
def mlp(cfg, p, x):
    """Gated (silu/geglu) or plain (gelu) MLP from a layer param dict."""
    if cfg.activation in ("silu", "geglu"):
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        g = act(jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype)))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
        return jnp.einsum("bsf,fd->bsd", g * u, p["wo"].astype(x.dtype))
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
                    + p["wi_bias"].astype(x.dtype))
    return (jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
            + p["wo_bias"].astype(x.dtype))


# ----------------------------------------------------------- embeddings ----
def embed_tokens(embedding, tokens, scale: float | None = None):
    x = jnp.take(embedding, tokens, axis=0).astype(jnp.bfloat16)
    if scale is not None:
        x = x * jnp.asarray(scale, dtype=x.dtype)
    return x


def lm_logits(x, out_embedding):
    """x [B,S,D] @ [V,D]^T -> [B,S,V] in f32."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      out_embedding.astype(jnp.float32))


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions; logits [B,S,V] f32, labels [B,S]."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
