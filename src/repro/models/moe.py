"""Mixture-of-Experts FFN with group-local sort-based capacity dispatch.

GShard-style semantics: each batch row is a dispatch *group* with capacity
C = ceil(S * top_k * cf / E).  Within a group, token->expert assignments are
sorted (a local [S*K] sort — never a cross-shard global sort) and gathered
into a static [B, E, C, D] buffer.  FLOPs stay proportional to *active*
experts, and the [B,S,.] -> [B,E,C,.] resharding (batch on ``data``, experts
on ``model``) is exactly the expert-parallel dispatch all-to-all, inserted
by GSPMD at the sharding constraint.  Avoids both the O(T*E*C) one-hot mask
(OOM at 128 experts x 1M tokens) and global sorts.  Router load-balance aux
loss follows Switch Transformer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.spec import TensorSpec as TS


def moe_specs(cfg: ModelConfig, n: int) -> dict:
    Lx, D, F, E = n, cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": TS((Lx, D, E), ("layers", "embed", None)),
        "wi_gate": TS((Lx, E, D, F), ("layers", "experts", "embed", "mlp")),
        "wi_up": TS((Lx, E, D, F), ("layers", "experts", "embed", "mlp")),
        "wo": TS((Lx, E, F, D), ("layers", "experts", "mlp", "embed")),
    }


def expert_only_specs(param_specs: dict):
    """Subtree of per-expert weights (for active-param accounting)."""
    out = {}

    def walk(tree, path):
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + (k,))
        else:
            if "experts" in (tree.axes or ()):
                out["/".join(path)] = tree

    walk(param_specs, ())
    return out


def group_capacity(group_tokens: int, cfg: ModelConfig) -> int:
    c = int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # >=8, rounded up to 8


def moe_ffn(cfg: ModelConfig, p, x, sh):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    dt = x.dtype
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    SK = S * K
    C = group_capacity(S, cfg)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt)
                        ).astype(jnp.float32)                        # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, K)                              # [B,S,K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss.
    frac = jnp.mean(jax.nn.one_hot(eid[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    # ---- group-local sorted dispatch ------------------------------------
    flat_e = eid.reshape(B, SK)
    order = jnp.argsort(flat_e, axis=-1, stable=True)                # [B,SK]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # per-group expert boundaries via batched searchsorted
    bounds = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(E + 1), side="left"))(sorted_e)              # [B,E+1]
    counts = bounds[:, 1:] - bounds[:, :-1]                          # [B,E]
    offsets = bounds[:, :-1]
    slot = offsets[:, :, None] + jnp.arange(C)[None, None, :]        # [B,E,C]
    valid = jnp.arange(C)[None, None, :] < counts[:, :, None]
    slot = jnp.clip(slot, 0, SK - 1)
    src = jnp.take_along_axis(order, slot.reshape(B, E * C), axis=-1)
    src_tok = src // K                                               # [B,E*C]

    gx = jnp.take_along_axis(x, src_tok[..., None], axis=1)          # [B,EC,D]
    gx = gx.reshape(B, E, C, D) * valid[..., None].astype(dt)
    gx = sh(gx, "batch", "experts", "capacity", "embed")  # dispatch a2a

    # ---- expert FFN (gated silu) ----------------------------------------
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", gx, p["wi_gate"].astype(dt)))
    u = jnp.einsum("becd,edf->becf", gx, p["wi_up"].astype(dt))
    eo = jnp.einsum("becf,efd->becd", g * u, p["wo"].astype(dt))     # [B,E,C,D]
    eo = sh(eo, "batch", "experts", "capacity", "embed")

    # ---- combine (gather-based: NO scatter) ------------------------------
    # Each token GATHERS its k expert outputs via the inverse sort
    # permutation.  A scatter-add combine forces GSPMD to replicate the
    # [B,S,D] f32 output across the data axis (8.6 GB all-reduces per layer
    # at train_4k); the gather keeps every index batch-local and everything
    # batch-sharded (EXPERIMENTS.md §Perf, pair B).
    inv = jnp.argsort(order, axis=-1)                     # rank of asgn i
    slot = inv - jnp.take_along_axis(offsets, flat_e, axis=-1)   # [B,SK]
    live = slot < C                                        # dropped if over
    slot = jnp.clip(slot, 0, C - 1)
    idx = flat_e * C + slot                                # [B,SK] into E*C
    eo_flat = eo.reshape(B, E * C, D)
    gathered = jnp.take_along_axis(eo_flat, idx[..., None], axis=1)  # [B,SK,D]
    w = (gate.reshape(B, SK) * live.astype(jnp.float32)).astype(dt)
    out = (gathered * w[..., None]).reshape(B, S, K, D).sum(axis=2)
    return out.astype(dt), aux
