"""Decoder-only transformer family: dense GQA (qwen2.5-*, gemma, h2o-danube),
MoE (qwen3-moe, dbrx) and VLM backbone (qwen2-vl, M-RoPE + vision stub).

Layers are scanned over stacked parameters (MaxText-style) to bound HLO size
and compile time; the layer body is rematerialized for training.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.spec import TensorSpec as TS, init_params


def _norm_specs(cfg, shape, axes):
    if cfg.norm == "layernorm":
        return {"scale": TS(shape, axes, init="ones"),
                "bias": TS(shape, axes, init="zeros")}
    return {"scale": TS(shape, axes, init="zeros")}


def attn_specs(cfg: ModelConfig, n: int) -> dict:
    Lx, D, H, Hk, Dh = (n, cfg.d_model, cfg.pad_heads_to or cfg.n_heads,
                        cfg.n_kv_heads, cfg.d_head)
    s: dict = {
        "wq": TS((Lx, D, H, Dh), ("layers", "embed", "heads", "head_dim")),
        "wk": TS((Lx, D, Hk, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": TS((Lx, D, Hk, Dh), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": TS((Lx, H, Dh, D), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias or cfg.norm == "layernorm":  # whisper has proj biases
        s["bq"] = TS((Lx, H, Dh), ("layers", "heads", "head_dim"), init="zeros")
        s["bk"] = TS((Lx, Hk, Dh), ("layers", "kv_heads", "head_dim"),
                     init="zeros")
        s["bv"] = TS((Lx, Hk, Dh), ("layers", "kv_heads", "head_dim"),
                     init="zeros")
    return s


def mlp_specs(cfg: ModelConfig, n: int) -> dict:
    Lx, D, F = n, cfg.d_model, cfg.d_ff
    if cfg.activation in ("silu", "geglu"):
        return {"wi_gate": TS((Lx, D, F), ("layers", "embed", "mlp")),
                "wi_up": TS((Lx, D, F), ("layers", "embed", "mlp")),
                "wo": TS((Lx, F, D), ("layers", "mlp", "embed"))}
    return {"wi": TS((Lx, D, F), ("layers", "embed", "mlp")),
            "wi_bias": TS((Lx, F), ("layers", "mlp"), init="zeros"),
            "wo": TS((Lx, F, D), ("layers", "mlp", "embed")),
            "wo_bias": TS((Lx, D), ("layers", "embed"), init="zeros")}


def attention(cfg: ModelConfig, p, x, positions, sh, *,
              window: int | None, cache=None, pos=None,
              memory=None, causal: bool = True, layer_axis: bool = False):
    """Full attention sub-layer (optionally cross-attention via ``memory``).

    cache: (k_cache, v_cache) [B, S, Hkv, Dh] for decode; pos [B].
    Returns (out, new_cache).
    """
    dt = x.dtype
    kv_src = memory if memory is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.rope_theta and not (memory is not None):
        sections = None
        if cfg.mrope:
            # Qwen2-VL uses (16, 24, 24) on d_half=64; scale proportionally.
            half = cfg.d_head // 2
            t = half // 4
            hw = (half - t) // 2
            sections = (half - 2 * hw, hw, hw)
        q = L.apply_rope(q, positions, cfg.rope_theta, sections)
        k = L.apply_rope(k, positions, cfg.rope_theta, sections)
    q = sh(q, "batch", "seq", "heads", "head_dim")
    # Padded heads (pad_heads_to): extra Q heads exist only so the head dim
    # divides the model axis.  They keep the ORIGINAL q->kv group mapping
    # for real heads (via an explicit gather) and are hard-masked to zero
    # output, so forward AND gradients are identical to the unpadded model.
    H_real, H_pad = cfg.n_heads, (cfg.pad_heads_to or cfg.n_heads)
    head_map = jnp.asarray(
        [min(h, H_real - 1) * cfg.n_kv_heads // H_real
         for h in range(H_pad)], jnp.int32)
    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        bidx = jnp.arange(k.shape[0])
        k_cache = k_cache.at[bidx, pos].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, pos].set(v[:, 0].astype(v_cache.dtype))
        new_cache = (k_cache, v_cache)
        attn = L.decode_attention(
            q, jnp.take(k_cache.astype(dt), head_map, axis=2),
            jnp.take(v_cache.astype(dt), head_map, axis=2),
            pos, window=window, repeated=True)
    else:
        attn = L.chunked_attention(q, jnp.take(k, head_map, axis=2),
                                   jnp.take(v, head_map, axis=2),
                                   causal=causal, window=window)
    if H_pad != H_real:
        mask = (jnp.arange(H_pad) < H_real).astype(dt)
        attn = attn * mask[None, None, :, None]
    attn = sh(attn, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"].astype(dt))
    return out, new_cache


class TransformerModel:
    """dense | moe | vlm decoder-only LM."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ specs ----
    def param_specs(self) -> dict:
        cfg = self.cfg
        n, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
        layer: dict = {"ln1": _norm_specs(cfg, (n, D), ("layers", "embed")),
                       "attn": attn_specs(cfg, n),
                       "ln2": _norm_specs(cfg, (n, D), ("layers", "embed"))}
        if cfg.is_moe:
            layer["moe"] = moe_lib.moe_specs(cfg, n)
        else:
            layer["mlp"] = mlp_specs(cfg, n)
        p = {"embed": TS((V, D), ("vocab", "embed"), init="embed"),
             "final_norm": _norm_specs(cfg, (D,), ("embed",)),
             "layers": layer}
        if not cfg.tie_embeddings:
            p["unembed"] = TS((V, D), ("vocab", "embed"), init="embed")
        return p

    def expert_param_specs(self):
        return moe_lib.expert_only_specs(self.param_specs())

    def init(self, key):
        return init_params(key, self.param_specs())

    # --------------------------------------------------------- positions ---
    def _positions(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        if not cfg.mrope:
            pos = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
            return jnp.broadcast_to(pos, (batch_size, seq_len))
        # M-RoPE: vision patches get (t=0, h, w) grid coords, text tokens get
        # t = h = w = running position (Qwen2-VL §2.1).
        P = min(cfg.n_frontend_tokens, seq_len)
        g = max(1, int(math.isqrt(P)))
        i = np.arange(seq_len)
        t = np.where(i < P, 0, i - P + g)
        h = np.where(i < P, np.minimum(i, P - 1) // g, i - P + g)
        w = np.where(i < P, np.minimum(i, P - 1) % g, i - P + g)
        pos3 = np.stack([t, h, w], axis=-1).astype(np.int32)  # [S,3]
        return jnp.broadcast_to(jnp.asarray(pos3)[None], (batch_size, seq_len, 3))

    def _decode_positions(self, pos):
        cfg = self.cfg
        if not cfg.mrope:
            return pos[:, None]
        P = cfg.n_frontend_tokens
        g = max(1, int(math.isqrt(P)))
        txt = pos - P + g
        return jnp.stack([txt, txt, txt], axis=-1)[:, None]  # [B,1,3]

    # ----------------------------------------------------------- embed -----
    def _embed(self, params, batch):
        cfg = self.cfg
        scale = math.sqrt(cfg.d_model) if cfg.name.startswith("gemma") else None
        x = L.embed_tokens(params["embed"], batch["tokens"], scale)
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            P = min(pe.shape[1], x.shape[1])
            x = jax.lax.dynamic_update_slice(x, pe[:, :P], (0, 0, 0))
        return x

    def _unembed(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["unembed"]

    # ---------------------------------------------------------- forward ----
    def _layer(self, params_i, x, positions, sh, window, cache_i=None,
               pos=None):
        cfg = self.cfg
        h = L.apply_norm(cfg, x, params_i["ln1"])
        attn_out, new_cache = attention(
            cfg, params_i["attn"], h, positions, sh,
            window=window, cache=cache_i, pos=pos)
        x = x + attn_out
        h = L.apply_norm(cfg, x, params_i["ln2"])
        if cfg.is_moe:
            ffn_out, aux = moe_lib.moe_ffn(cfg, params_i["moe"], h, sh)
        else:
            ffn_out, aux = L.mlp(cfg, params_i["mlp"], h), 0.0
        return x + ffn_out, aux, new_cache

    def forward(self, params, batch, sh=L.NO_SHARD, *, window=None):
        """Teacher-forced logits over the whole sequence. Returns (logits, aux)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        x = sh(x, "batch", "seq", "embed")
        positions = self._positions(*batch["tokens"].shape)
        window = window if window is not None else cfg.sliding_window

        def body(carry, params_i):
            x, aux = carry
            x, aux_i, _ = self._layer(params_i, x, positions, sh, window)
            return (x, aux + aux_i), None

        (x, aux), _ = L.scan_layers(body, (x, 0.0), params["layers"])
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.lm_logits(x, self._unembed(params))
        return sh(logits, "batch", "seq", "vocab"), aux

    def loss(self, params, batch, sh=L.NO_SHARD):
        logits, aux = self.forward(params, batch, sh)
        ce = L.softmax_cross_entropy(logits, batch["labels"])
        return ce + 0.01 * aux

    # ------------------------------------------------------------ serve ----
    def cache_specs(self, shape: InputShape, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        n = cfg.n_layers
        B, S = shape.global_batch, shape.seq_len
        kv = (n, B, S, cfg.n_kv_heads, cfg.d_head)
        axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": TS(kv, axes, dtype=dtype, init="zeros"),
                "v": TS(kv, axes, dtype=dtype, init="zeros")}

    def prefill(self, params, batch, sh=L.NO_SHARD, *, window=None):
        """Prefill logits (cache write-out elided in the benchmark shape —
        the assigned prefill shape measures the forward; see engine.serve
        for the cache-building variant)."""
        logits, _ = self.forward(params, batch, sh, window=window)
        return logits

    def decode_step(self, params, cache, batch, sh=L.NO_SHARD, *,
                    window=None):
        """One-token decode against a cache. batch: tokens [B,1], pos [B]."""
        cfg = self.cfg
        x = self._embed(params, batch)
        pos = batch["pos"]
        positions = self._decode_positions(pos)
        window = window if window is not None else cfg.sliding_window

        def body(x, xs):
            params_i, k_i, v_i = xs
            x, _, new_cache = self._layer(params_i, x, positions, sh, window,
                                          cache_i=(k_i, v_i), pos=pos)
            return x, new_cache

        x, (k_new, v_new) = L.scan_layers(
            body, x, (params["layers"], cache["k"], cache["v"]),
            checkpoint_body=False)
        x = L.apply_norm(cfg, x, params["final_norm"])
        logits = L.lm_logits(x, self._unembed(params))
        return logits, {"k": k_new, "v": v_new}

    # ------------------------------------------------------------ inputs ---
    def input_specs(self, shape: InputShape) -> dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = ("batch", "seq")
        if shape.kind == "train":
            d = {"tokens": TS((B, S), tok, dtype=jnp.int32),
                 "labels": TS((B, S), tok, dtype=jnp.int32)}
        elif shape.kind == "prefill":
            d = {"tokens": TS((B, S), tok, dtype=jnp.int32)}
        else:
            d = {"tokens": TS((B, 1), tok, dtype=jnp.int32),
                 "pos": TS((B,), ("batch",), dtype=jnp.int32)}
        if cfg.frontend == "vision" and shape.kind != "decode":
            d["patch_embeds"] = TS((B, cfg.n_frontend_tokens, cfg.d_model),
                                   ("batch", "patch", "embed"),
                                   dtype=jnp.bfloat16)
        return d
