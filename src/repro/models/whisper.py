"""Whisper-style encoder-decoder transformer backbone.

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings [B, n_frames, D].
Positional information is sinusoidal (computed on device — no giant constant
tables), pre-norm LayerNorm, GELU MLPs, biased projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import layers as L
from repro.models.spec import TensorSpec as TS, init_params
from repro.models.transformer import attn_specs, mlp_specs, attention


def sinusoidal(positions, d_model: int):
    """positions [B,S] -> [B,S,D] (classic transformer sinusoid)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(1, half - 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _layer_specs(self, n: int, cross: bool) -> dict:
        cfg = self.cfg
        D = cfg.d_model
        s = {"ln1": {"scale": TS((n, D), ("layers", "embed"), init="ones"),
                     "bias": TS((n, D), ("layers", "embed"), init="zeros")},
             "attn": attn_specs(cfg, n),
             "ln2": {"scale": TS((n, D), ("layers", "embed"), init="ones"),
                     "bias": TS((n, D), ("layers", "embed"), init="zeros")},
             "mlp": mlp_specs(cfg, n)}
        if cross:
            s["lnx"] = {"scale": TS((n, D), ("layers", "embed"), init="ones"),
                        "bias": TS((n, D), ("layers", "embed"), init="zeros")}
            s["xattn"] = attn_specs(cfg, n)
        return s

    def param_specs(self) -> dict:
        cfg = self.cfg
        V, D = cfg.vocab_size, cfg.d_model
        return {
            "embed": TS((V, D), ("vocab", "embed"), init="embed"),
            "unembed": TS((V, D), ("vocab", "embed"), init="embed"),
            "enc_norm": {"scale": TS((D,), ("embed",), init="ones"),
                         "bias": TS((D,), ("embed",), init="zeros")},
            "dec_norm": {"scale": TS((D,), ("embed",), init="ones"),
                         "bias": TS((D,), ("embed",), init="zeros")},
            "encoder": self._layer_specs(cfg.encoder_layers, cross=False),
            "decoder": self._layer_specs(cfg.n_layers, cross=True),
        }

    def init(self, key):
        return init_params(key, self.param_specs())

    # ----------------------------------------------------------- encoder ---
    def encode(self, params, frames, sh=L.NO_SHARD):
        cfg = self.cfg
        B, S, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = frames.astype(jnp.bfloat16) + sinusoidal(pos, cfg.d_model
                                                     ).astype(jnp.bfloat16)
        x = sh(x, "batch", "frames", "embed")
        positions = pos

        def body(x, p_i):
            h = L.layernorm(x, p_i["ln1"]["scale"], p_i["ln1"]["bias"])
            out, _ = attention(cfg, p_i["attn"], h, positions, sh,
                               window=None, causal=False)
            x = x + out
            h = L.layernorm(x, p_i["ln2"]["scale"], p_i["ln2"]["bias"])
            return x + L.mlp(cfg, p_i["mlp"], h), None

        x, _ = L.scan_layers(body, x, params["encoder"])
        return L.layernorm(x, params["enc_norm"]["scale"],
                           params["enc_norm"]["bias"])

    # ----------------------------------------------------------- decoder ---
    def _dec_layer(self, p_i, x, positions, enc, sh, cache_i=None, pos=None):
        cfg = self.cfg
        h = L.layernorm(x, p_i["ln1"]["scale"], p_i["ln1"]["bias"])
        out, new_cache = attention(cfg, p_i["attn"], h, positions, sh,
                                   window=None, cache=cache_i, pos=pos)
        x = x + out
        h = L.layernorm(x, p_i["lnx"]["scale"], p_i["lnx"]["bias"])
        out, _ = attention(cfg, p_i["xattn"], h, positions, sh,
                           window=None, memory=enc, causal=False)
        x = x + out
        h = L.layernorm(x, p_i["ln2"]["scale"], p_i["ln2"]["bias"])
        return x + L.mlp(cfg, p_i["mlp"], h), new_cache

    def forward(self, params, batch, sh=L.NO_SHARD, *, window=None):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], sh)
        B, S = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = L.embed_tokens(params["embed"], batch["tokens"])
        x = x + sinusoidal(pos, cfg.d_model).astype(x.dtype)
        x = sh(x, "batch", "seq", "embed")

        def body(x, p_i):
            x, _ = self._dec_layer(p_i, x, pos, enc, sh)
            return x, None

        x, _ = L.scan_layers(body, x, params["decoder"])
        x = L.layernorm(x, params["dec_norm"]["scale"],
                        params["dec_norm"]["bias"])
        return L.lm_logits(x, params["unembed"]), 0.0

    def loss(self, params, batch, sh=L.NO_SHARD):
        logits, _ = self.forward(params, batch, sh)
        return L.softmax_cross_entropy(logits, batch["labels"])

    def prefill(self, params, batch, sh=L.NO_SHARD, *, window=None):
        logits, _ = self.forward(params, batch, sh)
        return logits

    # ------------------------------------------------------------- serve ---
    def cache_specs(self, shape: InputShape, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        n, B, S = cfg.n_layers, shape.global_batch, shape.seq_len
        kv = (n, B, S, cfg.n_kv_heads, cfg.d_head)
        axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": TS(kv, axes, dtype=dtype, init="zeros"),
                "v": TS(kv, axes, dtype=dtype, init="zeros"),
                "enc": TS((B, cfg.n_frontend_tokens, cfg.d_model),
                          ("batch", "frames", "embed"), dtype=dtype,
                          init="zeros")}

    def decode_step(self, params, cache, batch, sh=L.NO_SHARD, *,
                    window=None):
        cfg = self.cfg
        pos = batch["pos"]
        x = L.embed_tokens(params["embed"], batch["tokens"])
        x = x + sinusoidal(pos[:, None], cfg.d_model).astype(x.dtype)
        enc = cache["enc"].astype(x.dtype)

        def body(x, xs):
            p_i, k_i, v_i = xs
            x, new_cache = self._dec_layer(p_i, x, pos[:, None], enc, sh,
                                           cache_i=(k_i, v_i), pos=pos)
            return x, new_cache

        x, (k_new, v_new) = L.scan_layers(
            body, x, (params["decoder"], cache["k"], cache["v"]),
            checkpoint_body=False)
        x = L.layernorm(x, params["dec_norm"]["scale"],
                        params["dec_norm"]["bias"])
        logits = L.lm_logits(x, params["unembed"])
        return logits, {"k": k_new, "v": v_new, "enc": cache["enc"]}

    def input_specs(self, shape: InputShape) -> dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        frames = TS((B, cfg.n_frontend_tokens, cfg.d_model),
                    ("batch", "frames", "embed"), dtype=jnp.bfloat16)
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": TS((B, S), ("batch", "seq"), dtype=jnp.int32),
                    "labels": TS((B, S), ("batch", "seq"), dtype=jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": TS((B, S), ("batch", "seq"), dtype=jnp.int32)}
        return {"tokens": TS((B, 1), ("batch", "seq"), dtype=jnp.int32),
                "pos": TS((B,), ("batch",), dtype=jnp.int32)}
