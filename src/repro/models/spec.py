"""Parameter specification pytrees.

Models declare their parameters as trees of :class:`TensorSpec` — shape,
dtype, logical axis names and an initializer tag.  From one spec tree we
derive, without duplication:

* real initialized parameters (smoke tests, examples, training),
* ``jax.ShapeDtypeStruct`` stand-ins (dry-run lowering, no allocation),
* ``NamedSharding`` trees (resolved through :mod:`repro.sharding.rules`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim (or None)
    dtype: Any = jnp.float32
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch")


def is_spec(x) -> bool:
    return isinstance(x, TensorSpec)


def _tree_map(fn: Callable[[TensorSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract(tree):
    """ShapeDtypeStruct tree — used by dry-run lowering (no allocation)."""
    return _tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def n_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in leaves)


def _init_one(key, s: TensorSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "embed":
        std = s.scale / math.sqrt(s.shape[-1])
        return (jax.random.normal(key, s.shape) * std).astype(s.dtype)
    if s.init == "normal":
        return (jax.random.normal(key, s.shape) * s.scale).astype(s.dtype)
    if s.init == "fan_in":
        # fan-in = product of all dims except the last output dim; for
        # stacked-layer params ignore the leading "layers" dim.
        dims = list(s.shape)
        fan_dims = dims[:-1]
        if s.axes and s.axes[0] == "layers":
            fan_dims = dims[1:-1]
        fan_in = max(1, int(np.prod(fan_dims)) if fan_dims else dims[-1])
        std = s.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, s.shape) * std).astype(s.dtype)
    raise ValueError(f"unknown init {s.init!r}")


def init_params(key, tree):
    """Materialize real parameters from a spec tree (deterministic)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(1, len(leaves)))
    vals = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def logical_axes(tree):
    """Tree of logical-axis tuples (same structure as the spec tree)."""
    return _tree_map(lambda s: s.axes, tree)


def cast(tree, dtype):
    """Spec tree with dtype replaced (e.g. bf16 serving params)."""
    return _tree_map(lambda s: dataclasses.replace(s, dtype=dtype), tree)
