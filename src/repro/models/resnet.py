"""ResNet (6n+2, non-bottleneck) for CIFAR-10 — the paper's own workload.

Pure-functional JAX; GroupNorm replaces BatchNorm so the model is stateless
(noted in DESIGN.md — convergence dynamics, which is what the paper's
scheduler models, are preserved).  Per-stage residual blocks after the first
are stacked and scanned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.spec import TensorSpec as TS, init_params


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def groupnorm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = xf.mean(axis=(1, 2, 4), keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=(1, 2, 4), keepdims=True)
    xf = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(b, h, w, c)
    return (xf * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def _block_specs(n, cin, cout):
    return {
        "conv1": TS((n, 3, 3, cin, cout), ("layers", None, None, None, None)),
        "n1s": TS((n, cout), ("layers", None), init="ones"),
        "n1b": TS((n, cout), ("layers", None), init="zeros"),
        "conv2": TS((n, 3, 3, cout, cout), ("layers", None, None, None, None)),
        "n2s": TS((n, cout), ("layers", None), init="ones"),
        "n2b": TS((n, cout), ("layers", None), init="zeros"),
    }


class ResNetModel:
    def __init__(self, cfg):
        self.cfg = cfg
        self.widths = [cfg.width, cfg.width * 2, cfg.width * 4]

    def param_specs(self) -> dict:
        cfg = self.cfg
        n = cfg.n
        p: dict = {"stem": TS((3, 3, 3, self.widths[0]),
                              (None, None, None, None)),
                   "stem_s": TS((self.widths[0],), (None,), init="ones"),
                   "stem_b": TS((self.widths[0],), (None,), init="zeros")}
        cin = self.widths[0]
        for si, cout in enumerate(self.widths):
            p[f"stage{si}_first"] = _block_specs(1, cin, cout)
            if n > 1:
                p[f"stage{si}_rest"] = _block_specs(n - 1, cout, cout)
            cin = cout
        p["fc"] = TS((self.widths[-1], cfg.num_classes), (None, None))
        p["fc_b"] = TS((cfg.num_classes,), (None,), init="zeros")
        return p

    def init(self, key):
        return init_params(key, self.param_specs())

    def _apply_block(self, p, x, stride=1):
        h = conv(x, p["conv1"], stride)
        h = jax.nn.relu(groupnorm(h, p["n1s"], p["n1b"]))
        h = conv(h, p["conv2"], 1)
        h = groupnorm(h, p["n2s"], p["n2b"])
        if stride != 1 or x.shape[-1] != h.shape[-1]:
            x = x[:, ::stride, ::stride, :]  # identity shortcut (option A)
            pad = h.shape[-1] - x.shape[-1]
            x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
        return jax.nn.relu(x + h)

    def apply(self, params, images):
        x = images.astype(jnp.bfloat16)
        x = jax.nn.relu(groupnorm(conv(x, params["stem"]),
                                  params["stem_s"], params["stem_b"]))
        n = self.cfg.n
        for si in range(3):
            stride = 1 if si == 0 else 2
            first = jax.tree_util.tree_map(lambda a: a[0],
                                           params[f"stage{si}_first"])
            x = self._apply_block(first, x, stride)
            if n > 1:
                def body(x, p_i):
                    return self._apply_block(p_i, x, 1), None
                x, _ = jax.lax.scan(jax.checkpoint(body), x,
                                    params[f"stage{si}_rest"])
        x = x.mean(axis=(1, 2)).astype(jnp.float32)
        return x @ params["fc"].astype(jnp.float32) + params["fc_b"]

    def loss(self, params, batch, sh=None):
        logits = self.apply(params, batch["images"])
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    def accuracy(self, params, batch):
        logits = self.apply(params, batch["images"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                        .astype(jnp.float32))
