"""Jamba-style hybrid: Mamba + attention 1:{attn_every-1} interleave with MoE
every ``moe_every``-th layer (arXiv:2403.19887).

A *block* of ``attn_every`` layers is the scan unit: the attention layer sits
at position ``attn_every // 2`` (Jamba places the first attention at layer 4),
MoE FFNs at odd positions.  Blocks are structurally identical, so their
params stack and the model scans over blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import mamba2 as m2
from repro.models.spec import TensorSpec as TS, init_params
from repro.models.transformer import attn_specs, mlp_specs, attention


class JambaModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.n_layers % cfg.attn_every == 0
        self.block_size = cfg.attn_every
        self.n_blocks = cfg.n_layers // cfg.attn_every
        self.attn_pos = cfg.attn_every // 2

    def _is_moe_pos(self, pos: int) -> bool:
        return self.cfg.is_moe and (pos % self.cfg.moe_every == 1)

    # ------------------------------------------------------------ specs ----
    def _pos_specs(self, pos: int) -> dict:
        cfg, nb = self.cfg, self.n_blocks
        D = cfg.d_model
        s: dict = {}
        if pos == self.attn_pos:
            s["ln1"] = {"scale": TS((nb, D), ("layers", "embed"),
                                    init="zeros")}
            s["attn"] = attn_specs(cfg, nb)
        else:
            s["mamba"] = m2.mamba_specs(cfg, nb)
        s["ln2"] = {"scale": TS((nb, D), ("layers", "embed"), init="zeros")}
        if self._is_moe_pos(pos):
            s["moe"] = moe_lib.moe_specs(cfg, nb)
        else:
            s["mlp"] = mlp_specs(cfg, nb)
        return s

    def param_specs(self) -> dict:
        cfg = self.cfg
        V, D = cfg.vocab_size, cfg.d_model
        return {"embed": TS((V, D), ("vocab", "embed"), init="embed"),
                "unembed": TS((V, D), ("vocab", "embed"), init="embed"),
                "final_norm": {"scale": TS((D,), ("embed",), init="zeros")},
                "blocks": {f"pos{p}": self._pos_specs(p)
                           for p in range(self.block_size)}}

    def expert_param_specs(self):
        return moe_lib.expert_only_specs(self.param_specs())

    def init(self, key):
        return init_params(key, self.param_specs())

    # ---------------------------------------------------------- forward ----
    def _block(self, bp, x, positions, sh, window, caches=None, pos=None):
        """One block of ``attn_every`` layers. caches: dict per position."""
        cfg = self.cfg
        aux_sum = 0.0
        new_caches = {}
        for p_i in range(self.block_size):
            p = bp[f"pos{p_i}"]
            if p_i == self.attn_pos:
                h = L.rmsnorm(x, p["ln1"]["scale"])
                cache_i = None
                if caches is not None:
                    cache_i = (caches[f"pos{p_i}"]["k"],
                               caches[f"pos{p_i}"]["v"])
                out, nc = attention(cfg, p["attn"], h, positions, sh,
                                    window=window, cache=cache_i, pos=pos)
                if nc is not None:
                    new_caches[f"pos{p_i}"] = {"k": nc[0], "v": nc[1]}
                x = x + out
            else:
                h = L.rmsnorm(x, p["mamba"]["norm"]["scale"])
                if caches is None:
                    x = x + m2.mamba_mixer(cfg, p["mamba"], h, sh)
                else:
                    out, st = m2.mamba_decode(cfg, p["mamba"], h,
                                              caches[f"pos{p_i}"], sh)
                    new_caches[f"pos{p_i}"] = st
                    x = x + out
            h = L.rmsnorm(x, p["ln2"]["scale"])
            if self._is_moe_pos(p_i):
                out, aux = moe_lib.moe_ffn(cfg, p["moe"], h, sh)
                aux_sum = aux_sum + aux
            else:
                out = L.mlp(cfg, p["mlp"], h)
            x = x + out
        return x, aux_sum, new_caches

    def forward(self, params, batch, sh=L.NO_SHARD, *, window=None):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"])
        x = sh(x, "batch", "seq", "embed")
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(carry, bp):
            x, aux = carry
            x, aux_i, _ = self._block(bp, x, positions, sh, window)
            return (x, aux + aux_i), None

        (x, aux), _ = L.scan_layers(body, (x, 0.0), params["blocks"])
        x = L.rmsnorm(x, params["final_norm"]["scale"])
        return L.lm_logits(x, params["unembed"]), aux

    def loss(self, params, batch, sh=L.NO_SHARD):
        logits, aux = self.forward(params, batch, sh)
        return L.softmax_cross_entropy(logits, batch["labels"]) + 0.01 * aux

    def prefill(self, params, batch, sh=L.NO_SHARD, *, window=None):
        logits, _ = self.forward(params, batch, sh, window=window)
        return logits

    # ------------------------------------------------------------ serve ----
    def cache_specs(self, shape: InputShape, dtype=jnp.bfloat16) -> dict:
        cfg, nb = self.cfg, self.n_blocks
        B, S = shape.global_batch, shape.seq_len
        H, P, N, K = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state,
                      cfg.ssm_conv)
        out: dict = {}
        for p in range(self.block_size):
            if p == self.attn_pos:
                kv = (nb, B, S, cfg.n_kv_heads, cfg.d_head)
                axes = ("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim")
                out[f"pos{p}"] = {"k": TS(kv, axes, dtype=dtype, init="zeros"),
                                  "v": TS(kv, axes, dtype=dtype, init="zeros")}
            else:
                out[f"pos{p}"] = {
                    "conv_x": TS((nb, B, K - 1, H, P),
                                 ("layers", "batch", "conv", "ssm_heads",
                                  "head_dim"), dtype=dtype, init="zeros"),
                    "conv_B": TS((nb, B, K - 1, N),
                                 ("layers", "batch", "conv", "ssm_state"),
                                 dtype=dtype, init="zeros"),
                    "conv_C": TS((nb, B, K - 1, N),
                                 ("layers", "batch", "conv", "ssm_state"),
                                 dtype=dtype, init="zeros"),
                    "ssm": TS((nb, B, H, P, N),
                              ("layers", "batch", "ssm_heads", "head_dim",
                               "ssm_state"), dtype=jnp.float32, init="zeros"),
                }
        return out

    def decode_step(self, params, cache, batch, sh=L.NO_SHARD, *,
                    window=None):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"])
        pos = batch["pos"]
        positions = pos[:, None]

        def body(x, xs):
            bp, caches = xs
            x, _, new_caches = self._block(bp, x, positions, sh, window,
                                           caches=caches, pos=pos)
            return x, new_caches

        x, new_cache = L.scan_layers(body, x, (params["blocks"], cache),
                                     checkpoint_body=False)
        x = L.rmsnorm(x, params["final_norm"]["scale"])
        return L.lm_logits(x, params["unembed"]), new_cache

    def input_specs(self, shape: InputShape) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": TS((B, S), ("batch", "seq"), dtype=jnp.int32),
                    "labels": TS((B, S), ("batch", "seq"), dtype=jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": TS((B, S), ("batch", "seq"), dtype=jnp.int32)}
        return {"tokens": TS((B, 1), ("batch", "seq"), dtype=jnp.int32),
                "pos": TS((B,), ("batch",), dtype=jnp.int32)}
