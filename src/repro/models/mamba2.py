"""Mamba-2 (SSD, state-space duality) in pure JAX.

Training/prefill uses the chunked SSD form: within a chunk of length Q the
quadratic (attention-like) branch runs on the MXU; across chunks a sequential
``lax.scan`` carries the [B, H, P, N] state.  Only one chunk's [B, H, Q, Q]
score block is live at a time.  Decode is the O(1) recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import layers as L
from repro.models.spec import TensorSpec as TS, init_params

NEG_INF = -1e30


def mamba_specs(cfg: ModelConfig, n: int) -> dict:
    D, H, P, N = cfg.d_model, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "norm": {"scale": TS((n, D), ("layers", "embed"), init="zeros")},
        "wz": TS((n, D, H, P), ("layers", "embed", "ssm_heads", "head_dim")),
        "wx": TS((n, D, H, P), ("layers", "embed", "ssm_heads", "head_dim")),
        "wB": TS((n, D, N), ("layers", "embed", "ssm_state")),
        "wC": TS((n, D, N), ("layers", "embed", "ssm_state")),
        "wdt": TS((n, D, H), ("layers", "embed", "ssm_heads")),
        "conv_x": TS((n, K, H, P), ("layers", "conv", "ssm_heads", "head_dim"),
                     init="normal", scale=0.5),
        "conv_B": TS((n, K, N), ("layers", "conv", "ssm_state"),
                     init="normal", scale=0.5),
        "conv_C": TS((n, K, N), ("layers", "conv", "ssm_state"),
                     init="normal", scale=0.5),
        "A_log": TS((n, H), ("layers", "ssm_heads"), init="zeros"),
        "D_skip": TS((n, H), ("layers", "ssm_heads"), init="ones"),
        "dt_bias": TS((n, H), ("layers", "ssm_heads"), init="zeros"),
        "gnorm": {"scale": TS((n, H, P), ("layers", "ssm_heads", "head_dim"),
                              init="zeros")},
        "wo": TS((n, H, P, D), ("layers", "ssm_heads", "head_dim", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv along axis 1. x: [B,S,...]; w: [K,...]."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, [(0, 0), (i, 0)] + [(0, 0)] * (x.ndim - 2)
                          )[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out


def _project(cfg, p, x):
    dt_ = x.dtype
    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"].astype(dt_))
    xin = jnp.einsum("bsd,dhp->bshp", x, p["wx"].astype(dt_))
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_))
    return z, xin, Bm, Cm, dt


def _finish(cfg, p, y, xin, z, dt, a):
    # y/D-skip/gate/out_proj shared by the chunked and decode paths.
    y = y + p["D_skip"].astype(y.dtype)[None, None, :, None] * xin
    y = y * jax.nn.silu(z)
    y = L.rmsnorm(y, p["gnorm"]["scale"])
    return jnp.einsum("bshp,hpd->bsd", y, p["wo"].astype(y.dtype))


def mamba_mixer(cfg: ModelConfig, p, x, sh):
    """Chunked SSD. x: [B, S, D] -> [B, S, D]."""
    dt_ = x.dtype
    B_, S, D = x.shape
    H, P, N, Q = cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_chunk
    z, xin, Bm, Cm, dt = _project(cfg, p, x)
    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"].astype(dt_)))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"].astype(dt_)))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"].astype(dt_)))
    xin = sh(xin, "batch", "seq", "ssm_heads", "head_dim")

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))        # [B,S,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                    # [H]
    dA = dt * a                                                     # [B,S,H]

    Q = min(Q, S)
    pad = (-S) % Q
    if pad:
        z, xin, Bm, Cm, dt, dA = [
            jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            for t in (z, xin, Bm, Cm, dt, dA)]
    nc = (S + pad) // Q

    def chunk(t):
        return t.reshape((B_, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    xin_c, Bm_c, Cm_c, dt_c, dA_c = map(chunk, (xin, Bm, Cm, dt, dA))

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)

    def body2(h, xs):
        xc, Bc, Cc, dtc, dAc = xs
        cs = jnp.cumsum(dAc, axis=1)                          # [B,Q,H]
        CB = jnp.einsum("bin,bjn->bij", Cc, Bc,
                        preferred_element_type=jnp.float32)
        diff = cs[:, :, None, :] - cs[:, None, :, :]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.exp(jnp.where(mask[None, :, :, None], diff, NEG_INF))
        M = CB[:, :, :, None] * decay * dtc[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M.astype(dt_), xc)
        # inter: [B,Q,H,P] = C[B,Q,N] . h[B,H,P,N] scaled by exp(cs)[B,Q,H]
        y_inter = jnp.einsum("bin,bhpn->bihp", Cc.astype(jnp.float32), h)
        y_inter = y_inter * jnp.exp(cs)[:, :, :, None]
        # state update: h' = h*exp(cs_Q) + sum_j exp(cs_Q - cs_j) dt_j B_j x_j
        w = jnp.exp(cs[:, -1:, :] - cs) * dtc                 # [B,Q,H]
        dh = jnp.einsum("bjh,bjn,bjhp->bhpn",
                        w, Bc.astype(jnp.float32), xc.astype(jnp.float32))
        h = h * jnp.exp(cs[:, -1])[:, :, None, None] + dh
        return h, (y_intra.astype(jnp.float32) + y_inter).astype(dt_)

    _, y = L.scan_layers(body2, h0, (xin_c, Bm_c, Cm_c, dt_c, dA_c))
    y = y.swapaxes(0, 1).reshape(B_, S + pad, H, P)[:, :S]
    return _finish(cfg, p, y, xin[:, :S], z[:, :S], dt[:, :S], a)


def mamba_decode(cfg: ModelConfig, p, x, state, sh):
    """One-token recurrence. x: [B, 1, D]; state dict with conv_*/ssm."""
    dt_ = x.dtype
    K = cfg.ssm_conv
    z, xin, Bm, Cm, dt = _project(cfg, p, x)

    def conv_step(buf, new, w):
        # buf [B, K-1, ...], new [B, 1, ...], w [K, ...]
        window = jnp.concatenate([buf, new], axis=1)          # [B,K,...]
        out = jnp.sum(window * w[None], axis=1, keepdims=True)
        return window[:, 1:], out

    cx, xin = conv_step(state["conv_x"], xin, p["conv_x"].astype(dt_))
    cB, Bm = conv_step(state["conv_B"], Bm, p["conv_B"].astype(dt_))
    cC, Cm = conv_step(state["conv_C"], Cm, p["conv_C"].astype(dt_))
    xin, Bm, Cm = jax.nn.silu(xin), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]   # [B,H]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    h = state["ssm"]                                                # [B,H,P,N]
    decay = jnp.exp(dt * a)[:, :, None, None]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm[:, 0].astype(jnp.float32),
                     xin[:, 0].astype(jnp.float32))
    h = h * decay + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y[:, None].astype(dt_)                                      # [B,1,H,P]
    out = _finish(cfg, p, y, xin, z, dt[:, None], a)
    return out, {"conv_x": cx, "conv_B": cB, "conv_C": cC, "ssm": h}


class Mamba2Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def param_specs(self) -> dict:
        cfg = self.cfg
        n, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
        return {"embed": TS((V, D), ("vocab", "embed"), init="embed"),
                "unembed": TS((V, D), ("vocab", "embed"), init="embed"),
                "final_norm": {"scale": TS((D,), ("embed",), init="zeros")},
                "layers": mamba_specs(cfg, n)}

    def init(self, key):
        return init_params(key, self.param_specs())

    def forward(self, params, batch, sh=L.NO_SHARD, *, window=None):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"])
        x = sh(x, "batch", "seq", "embed")

        def body(x, p_i):
            h = L.rmsnorm(x, p_i["norm"]["scale"])
            return x + mamba_mixer(cfg, p_i, h, sh), None

        x, _ = L.scan_layers(body, x, params["layers"])
        x = L.rmsnorm(x, params["final_norm"]["scale"])
        return L.lm_logits(x, params["unembed"]), 0.0

    def loss(self, params, batch, sh=L.NO_SHARD):
        logits, _ = self.forward(params, batch, sh)
        return L.softmax_cross_entropy(logits, batch["labels"])

    def prefill(self, params, batch, sh=L.NO_SHARD, *, window=None):
        logits, _ = self.forward(params, batch, sh)
        return logits

    def cache_specs(self, shape: InputShape, dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        n, B = cfg.n_layers, shape.global_batch
        H, P, N, K = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state,
                      cfg.ssm_conv)
        return {
            "conv_x": TS((n, B, K - 1, H, P),
                         ("layers", "batch", "conv", "ssm_heads", "head_dim"),
                         dtype=dtype, init="zeros"),
            "conv_B": TS((n, B, K - 1, N),
                         ("layers", "batch", "conv", "ssm_state"),
                         dtype=dtype, init="zeros"),
            "conv_C": TS((n, B, K - 1, N),
                         ("layers", "batch", "conv", "ssm_state"),
                         dtype=dtype, init="zeros"),
            "ssm": TS((n, B, H, P, N),
                      ("layers", "batch", "ssm_heads", "head_dim",
                       "ssm_state"), dtype=jnp.float32, init="zeros"),
        }

    def decode_step(self, params, cache, batch, sh=L.NO_SHARD, *,
                    window=None):
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], batch["tokens"])

        def body(x, xs):
            p_i, st = xs
            h = L.rmsnorm(x, p_i["norm"]["scale"])
            out, new_st = mamba_decode(cfg, p_i, h, st, sh)
            return x + out, new_st

        x, new_cache = L.scan_layers(body, x, (params["layers"], cache),
                                     checkpoint_body=False)
        x = L.rmsnorm(x, params["final_norm"]["scale"])
        return L.lm_logits(x, params["unembed"]), new_cache

    def input_specs(self, shape: InputShape) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": TS((B, S), ("batch", "seq"), dtype=jnp.int32),
                    "labels": TS((B, S), ("batch", "seq"), dtype=jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": TS((B, S), ("batch", "seq"), dtype=jnp.int32)}
        return {"tokens": TS((B, 1), ("batch", "seq"), dtype=jnp.int32),
                "pos": TS((B,), ("batch",), dtype=jnp.int32)}
