"""Model factory: config -> model object.

Every model exposes the same surface:
  param_specs() / init(key) / loss(params, batch, sh)
  prefill(params, batch, sh, window=) / decode_step(params, cache, batch, sh, window=)
  cache_specs(shape) / input_specs(shape)
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family == "ssm":
        from repro.models.mamba2 import Mamba2Model
        return Mamba2Model(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import JambaModel
        return JambaModel(cfg)
    if cfg.family == "audio":
        from repro.models.whisper import WhisperModel
        return WhisperModel(cfg)
    # dense / moe / vlm share the decoder-only transformer
    from repro.models.transformer import TransformerModel
    return TransformerModel(cfg)


def decode_window(cfg: ModelConfig, seq_len: int) -> int | None:
    """Effective attention window for a given context length.

    Native SWA archs always use their window; otherwise full attention up to
    128k and the sliding-window long-context variant beyond (the assignment's
    carve-out for long_500k on dense archs).
    """
    if cfg.sliding_window:
        return cfg.sliding_window
    if seq_len > 131_072:
        return cfg.long_context_window
    return None
