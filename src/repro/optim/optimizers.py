"""Optimizers (pure JAX, optax-style but self-contained).

SGD+momentum is the paper's optimizer (ResNet/CIFAR); AdamW serves the LLM
architectures.  States are pytrees mirroring the params, so checkpointing
and elastic restarts treat them uniformly.  On TPU the flat-buffer update is
handled by the fused Pallas kernel (repro.kernels.fused_update); these
jnp implementations are the portable reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params, lr)
    name: str = "opt"


def sgd(momentum: float = 0.9, weight_decay: float = 1e-4,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        def upd(g, mu, p):
            g = g + weight_decay * p
            mu_new = momentum * mu + g
            step = (g + momentum * mu_new) if nesterov else mu_new
            return p - lr * step, mu_new

        flat = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                            is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init, update, "sgd")


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"m": z,
                "v": jax.tree_util.tree_map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / c1
            vhat = v_new / c2
            step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p
            return p - lr * step, m_new, v_new

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                      params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda tup: tup[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "t": t}

    return Optimizer(init, update, "adamw")
