from repro.optim.optimizers import sgd, adamw, Optimizer
from repro.optim.schedule import step_decay, warmup_cosine, rescale_lr
