"""Learning-rate schedules + the paper's elastic rescale rule.

Eq. (7):  lr_new = (#GPUs_new / #GPUs_last) * lr_last  — linear scaling on
resize (Goyal et al.).  ``step_decay`` is the paper's ResNet schedule
(divide by 10 at epochs 100 and 150); decay *epoch* boundaries are held
fixed, so the step boundaries shift with global batch size exactly as §5
describes.
"""
from __future__ import annotations

from typing import Callable

Schedule = Callable[[int], float]


def rescale_lr(lr_last: float, gpus_new: int, gpus_last: int) -> float:
    """Paper eq. (7)."""
    return lr_last * (gpus_new / gpus_last)


def step_decay(base_lr: float, steps_per_epoch: float,
               boundaries_epochs=(100, 150), factor: float = 0.1) -> Schedule:
    def lr(step: int) -> float:
        epoch = step / max(steps_per_epoch, 1e-9)
        out = base_lr
        for b in boundaries_epochs:
            if epoch >= b:
                out *= factor
        return out
    return lr


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Schedule:
    import math

    def lr(step: int) -> float:
        if step < warmup:
            return base_lr * (step + 1) / warmup
        t = min(1.0, (step - warmup) / max(1, total - warmup))
        return base_lr * (min_frac + (1 - min_frac)
                          * 0.5 * (1 + math.cos(math.pi * t)))
    return lr
