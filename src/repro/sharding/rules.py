"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Models annotate every tensor dim with a logical axis name; a rule table maps
logical names to mesh axes.  ``resolve`` checks divisibility against the
actual mesh and falls back to replication when a dim does not divide (e.g.
40 query heads or vocab 51865 on a 16-way ``model`` axis), so one rule table
serves every architecture and mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import spec as pspec

# Default logical → mesh-axis rules for the production meshes.
#   batch:   data parallel (both pod and data axes when multi-pod)
#   heads / kv_heads / mlp / vocab / experts: tensor/expert parallel
#   cache_seq: context-parallel long decode (KV cache sharded along seq)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "ssm_heads": ("model",),
    "cache_seq": ("data",),
    # never sharded:
    "layers": (), "embed": (), "seq": (), "ssm_state": (), "head_dim": (),
    "conv": (), "chunks": (), "capacity": (), "patch": (), "frames": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: Mapping[str, tuple[str, ...]]

    def mesh_axes_for(self, logical: str | None, mesh: Mesh) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.table.get(logical)
        if axes is None:
            return ()
        return tuple(a for a in axes if a in mesh.shape)

    def resolve_dim(self, logical: str | None, size: int, mesh: Mesh,
                    used: set[str]) -> tuple[str, ...] | None:
        axes = tuple(a for a in self.mesh_axes_for(logical, mesh)
                     if a not in used)
        if not axes:
            return None
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if size % total != 0:
            # try a prefix of the axes (e.g. drop "pod" but keep "data")
            while axes:
                axes = axes[:-1]
                total = 1
                for a in axes:
                    total *= mesh.shape[a]
                if axes and size % total == 0:
                    break
            if not axes:
                return None
        used.update(axes)
        return axes if len(axes) > 1 else (axes[0],)

    def spec_for(self, axes: Sequence[str | None], shape: Sequence[int],
                 mesh: Mesh) -> P:
        used: set[str] = set()
        parts: list = []
        for name, size in zip(axes, shape):
            r = self.resolve_dim(name, size, mesh, used)
            if r is None:
                parts.append(None)
            elif len(r) == 1:
                parts.append(r[0])
            else:
                parts.append(r)
        # Secondary fallback: when a heads-like dim could not shard (e.g.
        # 40 q-heads or 8 kv-heads on a 16-way "model" axis), shard head_dim
        # instead so attention weights/activations never replicate fully.
        if "model" in mesh.shape and "model" not in used:
            # only when a *query/ssm* heads dim failed to shard — kv-only
            # tensors stay replicated (Megatron GQA convention) so q and kv
            # projections keep consistent layouts per architecture.
            wanted_model = any(
                n in ("heads", "ssm_heads") and parts[i] is None
                for i, n in enumerate(axes))
            if wanted_model:
                for i, (name, size) in enumerate(zip(axes, shape)):
                    if (name == "head_dim" and parts[i] is None
                            and size % mesh.shape["model"] == 0):
                        parts[i] = "model"
                        used.add("model")
                        break
        return P(*parts)


def default_rules(overrides: Mapping[str, tuple[str, ...]] | None = None
                  ) -> ShardingRules:
    table = dict(DEFAULT_RULES)
    if overrides:
        table.update(overrides)
    return ShardingRules(table)


def tree_pspecs(spec_tree, mesh: Mesh, rules: ShardingRules | None = None):
    """PartitionSpec tree mirroring a TensorSpec tree."""
    rules = rules or default_rules()
    return jax.tree_util.tree_map(
        lambda s: rules.spec_for(s.axes, s.shape, mesh),
        spec_tree, is_leaf=pspec.is_spec)


def tree_shardings(spec_tree, mesh: Mesh, rules: ShardingRules | None = None):
    """NamedSharding tree mirroring a TensorSpec tree."""
    rules = rules or default_rules()
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, rules.spec_for(s.axes, s.shape, mesh)),
        spec_tree, is_leaf=pspec.is_spec)
