"""All-reduce algorithm simulators with first-principles cost counters.

The paper (§2.1, §3.2) models three algorithms: *ring*, *doubling–halving*
(recursive halving/doubling, Rabenseifner) and *binary blocks* (non-power-
of-two w).  Each simulator executes the algorithm step-by-step over numpy
vectors — producing the exact all-reduce result — while counting the
latency/bandwidth/compute terms (α messages, β bytes, γ reduced bytes) that
eqs. (2)–(4) model.  The counters cross-validate the analytic cost models in
``repro.collectives.cost`` (see tests/test_collectives_cost.py).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np


@dataclasses.dataclass
class CommStats:
    """Per-rank worst-case counters over the whole all-reduce."""
    steps: int = 0            # sequential message rounds (α count)
    bytes_sent: float = 0.0   # per-rank bytes transferred (β count)
    bytes_reduced: float = 0.0  # per-rank bytes combined (γ count)

    def time(self, alpha: float, beta: float, gamma: float) -> float:
        return (self.steps * alpha + self.bytes_sent * beta
                + self.bytes_reduced * gamma)


def _split_sizes(n: int, w: int) -> list[int]:
    base, rem = divmod(n, w)
    return [base + (1 if i < rem else 0) for i in range(w)]


def ring_allreduce(vectors: np.ndarray, itemsize: int = 4
                   ) -> tuple[np.ndarray, CommStats]:
    """Classic ring: reduce-scatter (w-1 steps) + all-gather (w-1 steps)."""
    w, n = vectors.shape
    out = vectors.astype(np.float64).copy()
    stats = CommStats()
    if w == 1:
        return out, stats
    sizes = _split_sizes(n, w)
    bounds = np.cumsum([0] + sizes)
    seg = lambda i: slice(bounds[i % w], bounds[i % w + 1])

    # reduce-scatter: at step t, rank r sends segment (r - t) to rank r+1
    for t in range(w - 1):
        incoming = [out[(r - 1) % w, seg(r - 1 - t)].copy() for r in range(w)]
        for r in range(w):
            out[r, seg(r - 1 - t)] += incoming[r]
        stats.steps += 1
        stats.bytes_sent += max(sizes) * itemsize
        stats.bytes_reduced += max(sizes) * itemsize
    # all-gather: rank r owns segment (r+1); circulate w-1 steps
    for t in range(w - 1):
        incoming = [out[(r - 1) % w, seg(r - t)].copy() for r in range(w)]
        for r in range(w):
            out[r, seg(r - t)] = incoming[r]
        stats.steps += 1
        stats.bytes_sent += max(sizes) * itemsize
    return out, stats


def halving_doubling_allreduce(vectors: np.ndarray, itemsize: int = 4
                               ) -> tuple[np.ndarray, CommStats]:
    """Rabenseifner recursive halving (reduce-scatter) + doubling (gather).

    Only valid for w a power of two (the paper's doubling heuristic exists
    precisely to keep allocations on powers of two).
    """
    w, n = vectors.shape
    assert w & (w - 1) == 0, "halving-doubling requires power-of-two w"
    out = vectors.astype(np.float64).copy()
    stats = CommStats()
    if w == 1:
        return out, stats

    # Track each rank's owned interval [lo, hi) of the vector.
    lo = np.zeros(w, dtype=int)
    hi = np.full(w, n, dtype=int)
    steps = int(np.log2(w))
    for i in range(steps):
        dist = 2 ** i
        newlo, newhi = lo.copy(), hi.copy()
        for r in range(w):  # update owned intervals (keep half)
            mid = (lo[r] + hi[r]) // 2
            if r & dist:
                newlo[r], newhi[r] = mid, hi[r]
            else:
                newlo[r], newhi[r] = lo[r], mid
        # each rank receives its partner's sent half (the half the partner
        # does NOT keep == the half this rank keeps)
        buf = {}
        for r in range(w):
            p = r ^ dist
            a, b = newlo[r], newhi[r]
            buf[r] = (a, b, out[p, a:b].copy())
        for r in range(w):
            a, b, data = buf[r]
            out[r, a:b] += data
        lo, hi = newlo, newhi
        seg_bytes = (n / (2 ** (i + 1))) * itemsize
        stats.steps += 1
        stats.bytes_sent += seg_bytes
        stats.bytes_reduced += seg_bytes
    # doubling: reverse exchanges, each rank fills its partner's interval
    for i in reversed(range(steps)):
        dist = 2 ** i
        buf = {}
        for r in range(w):
            p = r ^ dist
            buf[r] = (lo[p], hi[p], out[p, lo[p]:hi[p]].copy())
        for r in range(w):
            a, b, data = buf[r]
            out[r, a:b] = data
            lo[r], hi[r] = min(lo[r], a), max(hi[r], b)
        stats.steps += 1
        stats.bytes_sent += (n / (2 ** (i + 1))) * itemsize
    return out, stats


def binary_blocks_allreduce(vectors: np.ndarray, itemsize: int = 4
                            ) -> tuple[np.ndarray, CommStats]:
    """Binary-blocks (Rabenseifner §4): decompose w = Σ 2^{b_i}; run
    halving-doubling inside each block, fold small blocks into larger ones,
    then redistribute.  Exact result; counters are per-rank worst case."""
    w, n = vectors.shape
    out = vectors.astype(np.float64).copy()
    stats = CommStats()
    if w == 1:
        return out, stats
    if w & (w - 1) == 0:
        return halving_doubling_allreduce(vectors, itemsize)

    # block decomposition, largest first: e.g. 11 = 8 + 2 + 1
    blocks = []
    start = 0
    rem = w
    while rem:
        b = 1 << (rem.bit_length() - 1)
        blocks.append((start, b))
        start += b
        rem -= b

    # intra-block reduce (halving-doubling result held at every block member)
    reduced = []
    worst = CommStats()
    for (s, b) in blocks:
        blk, st = halving_doubling_allreduce(out[s:s + b], itemsize)
        out[s:s + b] = blk
        reduced.append(blk[0])
        worst.steps = max(worst.steps, st.steps)
        worst.bytes_sent = max(worst.bytes_sent, st.bytes_sent)
        worst.bytes_reduced = max(worst.bytes_reduced, st.bytes_reduced)
    stats.steps += worst.steps
    stats.bytes_sent += worst.bytes_sent
    stats.bytes_reduced += worst.bytes_reduced

    # fold block partials into the big block (smallest -> next, pairwise),
    # one extra message round per extra block
    total = reduced[0].copy()
    for extra in reduced[1:]:
        total += extra
        stats.steps += 1
        stats.bytes_sent += n * itemsize
        stats.bytes_reduced += n * itemsize
    # broadcast back to all blocks (one round per extra block)
    for (s, b) in blocks:
        out[s:s + b] = total
    stats.steps += len(blocks) - 1
    stats.bytes_sent += (len(blocks) - 1) * n * itemsize
    return out, stats


ALGORITHMS = {
    "ring": ring_allreduce,
    "doubling_halving": halving_doubling_allreduce,
    "binary_blocks": binary_blocks_allreduce,
}


@functools.lru_cache(maxsize=4096)
def best_algorithm(w: int, n_bytes: float, threshold: float = 1e7) -> str:
    """Paper §2.1: doubling-halving wins for parameter sizes up to ~1e7 at
    power-of-two w; binary blocks otherwise; ring for very large tensors.

    LRU-cached: the scheduler hot path asks for the same (w, n) pairs over
    and over when building analytic speed tables.
    """
    if w & (w - 1) == 0:
        return "doubling_halving" if n_bytes <= threshold else "ring"
    return "binary_blocks"
