"""Executable ring / halving-doubling all-reduce on a JAX mesh axis.

These are the paper's gradient-exchange algorithms expressed TPU-natively:
``lax.ppermute`` neighbor/pair exchanges inside ``shard_map`` — the explicit
`grad_exchange` mode of the trainer.  Results match ``lax.psum`` bit-for-bit
up to float association order (validated in tests with 8 host devices).

Binary-blocks is deliberately NOT given an executable path: TPU meshes are
power-of-two tori, so the non-power-of-two case the algorithm exists for
cannot arise (DESIGN.md §3); it remains covered by the numpy schedule
simulator and the analytic cost model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _pad_to(x, k):
    n = x.shape[0]
    pad = (-n) % k
    if pad:
        x = jnp.pad(x, ((0, pad),))
    return x, n


def ring_allreduce(x, axis: str):
    """Ring all-reduce of a 1-D vector along a mesh axis (inside shard_map).

    reduce-scatter: w-1 ppermute steps, n/w bytes each; then all-gather:
    w-1 more.  Mirrors repro.collectives.schedules.ring_allreduce.
    """
    w = lax.axis_size(axis)
    if w == 1:
        return x
    r = lax.axis_index(axis)
    xp, n = _pad_to(x, w)
    seg = xp.shape[0] // w
    segs = xp.reshape(w, seg)
    perm = [(i, (i + 1) % w) for i in range(w)]

    # ---- reduce-scatter: at step t, send segment (r - t) ----
    def rs_step(t, segs):
        flat = segs.reshape(-1)
        send_idx = (r - t) % w
        send = lax.dynamic_slice_in_dim(flat, send_idx * seg, seg, 0)
        recv = lax.ppermute(send, axis, perm)
        recv_idx = (r - t - 1) % w
        cur = lax.dynamic_slice_in_dim(flat, recv_idx * seg, seg, 0)
        return lax.dynamic_update_slice_in_dim(
            flat, cur + recv, recv_idx * seg, 0).reshape(w, seg)

    segs = lax.fori_loop(0, w - 1, rs_step, segs)

    # ---- all-gather: rank r now owns segment (r + 1) ----
    def ag_step(t, segs):
        send_idx = (r + 1 - t) % w
        send = lax.dynamic_slice_in_dim(segs.reshape(-1), send_idx * seg, seg,
                                        0)
        recv = lax.ppermute(send, axis, perm)
        recv_idx = (r - t) % w
        return lax.dynamic_update_slice_in_dim(
            segs.reshape(-1), recv, recv_idx * seg, 0).reshape(w, seg)

    segs = lax.fori_loop(0, w - 1, ag_step, segs)
    return segs.reshape(-1)[:n]


def halving_doubling_allreduce(x, axis: str):
    """Rabenseifner recursive halving/doubling along a power-of-two axis."""
    w = lax.axis_size(axis)
    if w == 1:
        return x
    assert w & (w - 1) == 0, "halving-doubling requires power-of-two w"
    steps = w.bit_length() - 1
    r = lax.axis_index(axis)
    xp, n = _pad_to(x, w)
    N = xp.shape[0]

    # Recursive halving (reduce-scatter). Owned interval tracked via traced
    # offsets; buffer stays full-size, only the owned half is meaningful.
    lo = jnp.int32(0)
    size = N
    buf = xp
    for i in range(steps):
        dist = 1 << i
        perm = [(j, j ^ dist) for j in range(w)]
        half = size // 2
        bit = (r // dist) % 2          # 0: keep lower, send upper
        keep_lo = lo + bit * half
        send_lo = lo + (1 - bit) * half
        send = lax.dynamic_slice_in_dim(buf, send_lo, half, 0)
        recv = lax.ppermute(send, axis, perm)
        kept = lax.dynamic_slice_in_dim(buf, keep_lo, half, 0)
        buf = lax.dynamic_update_slice_in_dim(buf, kept + recv, keep_lo, 0)
        lo = keep_lo
        size = half

    # Recursive doubling (all-gather)
    for i in reversed(range(steps)):
        dist = 1 << i
        perm = [(j, j ^ dist) for j in range(w)]
        send = lax.dynamic_slice_in_dim(buf, lo, size, 0)
        recv = lax.ppermute(send, axis, perm)
        bit = (r // dist) % 2
        partner_lo = lo + jnp.where(bit == 1, -size, size)
        buf = lax.dynamic_update_slice_in_dim(buf, recv, partner_lo, 0)
        lo = jnp.minimum(lo, partner_lo)
        size = size * 2
    return buf[:n]


ALGORITHMS = {"ring": ring_allreduce,
              "doubling_halving": halving_doubling_allreduce,
              "psum": lambda x, axis: lax.psum(x, axis)}


def exchange_tree(tree, axis: str, algorithm: str = "ring"):
    """Horovod-style gradient exchange, usable INSIDE shard_map: flatten the
    per-device gradient tree into one fusion buffer, all-reduce it with the
    chosen explicit algorithm, unflatten."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    summed = ALGORITHMS[algorithm](flat, axis)
    out_leaves = []
    off = 0
    for shp, sz, dt in zip(shapes, sizes, dtypes):
        out_leaves.append(summed[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
