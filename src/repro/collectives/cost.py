"""Analytic per-minibatch time models — paper §3.2 eqs. (2)–(4) verbatim.

α: latency per message [s]; β: transfer time per byte [s/B];
γ: compute cost per vector byte [s/B]; n: model gradient size [bytes];
m: per-worker minibatch; w: workers.

``HardwareCoefficients`` maps the constants onto the TPU v5e target (ICI hop
latency / link bandwidth / VPU reduce throughput) — the functional form is
unchanged (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.collectives.schedules import ALGORITHMS, best_algorithm


@dataclasses.dataclass(frozen=True)
class HardwareCoefficients:
    alpha: float = 1e-6       # ICI hop latency ~1us
    beta: float = 1.0 / 45e9  # per-byte on a ~45GB/s effective ICI link
    gamma: float = 1.0 / 400e9  # VPU reduce bytes/s
    name: str = "tpu_v5e"


TPU_V5E = HardwareCoefficients()
# The paper's cluster: 100 Gbit/s (4x EDR) InfiniBand, K40m-era hosts.
INFINIBAND_100G = HardwareCoefficients(
    alpha=2e-6, beta=1.0 / 12.5e9, gamma=1.0 / 50e9, name="ib_100g")


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One physical node of the cluster: a GPU count plus (optionally) its
    own :class:`HardwareCoefficients` for heterogeneous fleets.  ``hw=None``
    means the node runs at the cluster-wide coefficients."""
    gpus: int
    hw: HardwareCoefficients | None = None

    def __post_init__(self):
        if self.gpus < 1:
            raise ValueError(f"NodeSpec.gpus must be >= 1, got {self.gpus}")


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """The cluster the §7 simulation schedules over.

    The paper treats the cluster as a flat homogeneous GPU count; GADGET
    (arXiv 2202.01158) and the multi-tenant contention follow-up (arXiv
    2207.07817) show ring-all-reduce scheduling changes materially once
    placement, link bandwidth and communication contention enter the
    model.  This dataclass owns all of it:

      * ``capacity`` — total GPUs (the paper's C).
      * ``hw`` — intra-node :class:`HardwareCoefficients` (α/β/γ).
      * ``gpus_per_node`` / ``inter_node_beta`` — optional node topology.
        A job whose ring spans nodes (w > gpus_per_node) pays the slower
        cross-node per-byte time ``inter_node_beta`` instead of ``hw.beta``;
        its speed table is scaled by the analytic intra/inter step-time
        ratio (see ``JobSpec.speed_table``).  ``None`` (the default) is
        the paper's flat single-fabric cluster.
      * ``contention_penalty`` — GADGET-style multi-tenant link sharing:
        when k concurrent jobs run ring all-reduce (w >= 2), each of them
        progresses at ``contention_factor(k) = 1 / (1 + penalty*(k-1))``
        of its nominal speed.  0.0 (default) disables it.  With a
        placement engine active only *node-spanning* rings contend (they
        share the inter-node fabric; intra-node rings never touch it).
      * ``restart_cost`` — checkpoint-stop-restart pause per reallocation
        (~10 s measured, paper §6).
      * ``nodes`` — explicit per-node layout (tuple of :class:`NodeSpec`)
        for heterogeneous fleets; requires ``placement``.  Mutually
        exclusive with ``gpus_per_node``, and the GPU counts must sum to
        ``capacity``.
      * ``placement`` — name of a registered
        :class:`repro.core.placement.PlacementStrategy` (``"packed"``,
        ``"spread"``, ``"best_fit"``).  When set, both simulator engines
        run the node-level placement engine: each gang gets a concrete
        per-node assignment, spanning/contention status derives from the
        *actual* assignment under fragmentation (not the
        ``w > gpus_per_node`` shortcut), and policies see the flat speed
        tables plus a placement view.  ``None`` (default) keeps the
        legacy behavior.
      * ``admission`` — name of a registered admission rule
        (``"admit_all"``, ``"queue_cap_<n>"``, ``"free_gpus_<k>"``);
        non-default rules require ``placement``.
      * ``defrag`` — run the migration/defragmentation pass: at each
        reallocation event, a node-spanning gang that now fits on a
        single node is consolidated there, charging ``restart_cost``
        (the gang moves).  Requires ``placement``.
      * ``faults`` — name of a registered
        :class:`repro.core.faults.FaultModel` (``"none"``,
        ``"kill_<t>"``, ``"churn_<n>"``, ``"drain_<t>"``,
        ``"stragglers_<k>"``, ``"rack_<t>"``) or an instance; with
        ``fault_seed`` it yields one deterministic incident tape per
        run, delivered identically by both simulator engines.  Requires
        ``placement`` (failures act on concrete node assignments).
      * ``fault_seed`` — seed for the fault schedule (independent of the
        workload seed, so the same trace can face different churn).
      * ``checkpoint_interval`` — progress-seconds between checkpoints
        for the lost-work charge on eviction
        (:class:`repro.core.faults.CheckpointPolicy`); ``None`` uses
        ``faults.DEFAULT_CHECKPOINT_INTERVAL``.  Requires ``faults``.

    A flat homogeneous ClusterModel (defaults) reproduces the paper setup
    bit-identically — the engines and speed tables take the exact same
    code paths as a bare integer capacity.  A placement engine over a
    single node (``placement`` set, no topology) is a structural no-op:
    nothing ever spans, every factor is exactly 1.0, and trajectories
    stay bit-identical to the flat cluster (golden-value-tested).
    """
    capacity: int = 64
    hw: HardwareCoefficients = INFINIBAND_100G
    gpus_per_node: int | None = None
    inter_node_beta: float | None = None
    contention_penalty: float = 0.0
    restart_cost: float = 10.0
    nodes: tuple[NodeSpec, ...] | None = None
    placement: str | None = None
    admission: str = "admit_all"
    defrag: bool = False
    faults: object | None = None        # str spec or faults.FaultModel
    fault_seed: int = 0
    checkpoint_interval: float | None = None

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.nodes is not None:
            if self.gpus_per_node is not None:
                raise ValueError(
                    "pass either nodes (explicit layout) or gpus_per_node "
                    "(uniform layout), not both")
            if self.placement is None:
                raise ValueError(
                    "nodes without placement does nothing — node-level "
                    "layouts are consumed by the placement engine")
            total = sum(n.gpus for n in self.nodes)
            if total != self.capacity:
                raise ValueError(
                    f"nodes sum to {total} GPUs but capacity is "
                    f"{self.capacity}; make them agree")
            if len(self.nodes) > 1 and self.inter_node_beta is None:
                raise ValueError(
                    "a multi-node ClusterModel needs inter_node_beta "
                    "(cross-node per-byte transfer time)")
        if self.gpus_per_node is not None:
            if self.gpus_per_node < 1:
                raise ValueError(
                    f"gpus_per_node must be >= 1, got {self.gpus_per_node}")
            if self.inter_node_beta is None:
                raise ValueError(
                    "a multi-node ClusterModel needs inter_node_beta "
                    "(cross-node per-byte transfer time)")
        elif self.inter_node_beta is not None and self.nodes is None:
            # the symmetric mistake: a cross-node β without a node size
            # would silently reproduce flat-cluster results
            raise ValueError(
                "inter_node_beta without gpus_per_node does nothing — "
                "set both (multi-node) or neither (flat)")
        if self.inter_node_beta is not None:
            betas = [self.hw.beta] + [n.hw.beta for n in (self.nodes or ())
                                      if n.hw is not None]
            if self.inter_node_beta < max(betas):
                raise ValueError(
                    "inter_node_beta is faster than the intra-node link "
                    f"({self.inter_node_beta} < {max(betas)})")
        if self.contention_penalty < 0.0:
            raise ValueError(
                f"contention_penalty must be >= 0, got "
                f"{self.contention_penalty}")
        if self.placement is not None:
            # deferred import: placement builds on this module
            from repro.core.placement import get_admission, get_placement
            get_placement(self.placement)          # loud unknown-name error
            get_admission(self.admission).validate(self)
        elif self.admission != "admit_all":
            raise ValueError(
                "an admission rule without placement does nothing — set "
                "placement (a single-node placement engine is a no-op) "
                "or drop admission")
        elif self.defrag:
            raise ValueError(
                "defrag without placement does nothing — the migration "
                "pass moves gangs the placement engine placed")
        if self.faults is not None:
            if self.placement is None:
                raise ValueError(
                    "faults without placement does nothing — failures "
                    "act on concrete node assignments; set placement "
                    "(a single-node placement engine is otherwise a "
                    "no-op)")
            # deferred import: faults builds on the scheduler registry
            from repro.core.faults import get_fault_model
            get_fault_model(self.faults).validate(self)
        if self.checkpoint_interval is not None:
            if self.faults is None:
                raise ValueError(
                    "checkpoint_interval without faults does nothing — "
                    "lost work is only charged on eviction")
            if self.checkpoint_interval <= 0.0:
                raise ValueError(
                    f"checkpoint_interval must be > 0, got "
                    f"{self.checkpoint_interval}")

    @property
    def is_flat(self) -> bool:
        """True when this is the paper's flat homogeneous cluster."""
        return (self.gpus_per_node is None and self.contention_penalty == 0.0
                and self.placement is None)

    def node_specs(self) -> tuple[NodeSpec, ...]:
        """The node-level layout the placement engine schedules over:
        ``nodes`` verbatim, or ``capacity`` split into uniform
        ``gpus_per_node`` chunks (last node partial), or one node holding
        the whole flat cluster."""
        if self.nodes is not None:
            return self.nodes
        if self.gpus_per_node is None:
            return (NodeSpec(gpus=self.capacity),)
        full, rest = divmod(self.capacity, self.gpus_per_node)
        out = [NodeSpec(gpus=self.gpus_per_node) for _ in range(full)]
        if rest:
            out.append(NodeSpec(gpus=rest))
        return tuple(out)

    def spans_nodes(self, w) -> bool | np.ndarray:
        """Whether a w-worker ring crosses node boundaries (scalar or
        ndarray w)."""
        if self.gpus_per_node is None:
            return np.zeros_like(np.asarray(w), bool) if np.ndim(w) else False
        return np.asarray(w) > self.gpus_per_node

    def inter_hw(self) -> HardwareCoefficients:
        """Coefficients a node-spanning ring sees: cross-node β."""
        return dataclasses.replace(self.hw, beta=self.inter_node_beta,
                                   name=f"{self.hw.name}+inter")

    def contention_factor(self, n_comm: int) -> float:
        """Speed multiplier for each of ``n_comm`` concurrent ring jobs."""
        if n_comm <= 1 or self.contention_penalty == 0.0:
            return 1.0
        return 1.0 / (1.0 + self.contention_penalty * (n_comm - 1))


def _log2(w):
    """Elementwise log2 with the scalar convention lw(w<=1) = 0.

    np.log2 and math.log2 agree bit-for-bit on every integer worker count
    we ever pass (checked up to 1024), so the vectorized forms reproduce
    the original scalar results exactly.
    """
    w = np.asarray(w, float)
    return np.where(w > 1.0, np.log2(np.maximum(w, 1.0)), 0.0)


def t_ring(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E):
    """Eq. (2): ring algorithm.  ``w`` may be a scalar or an ndarray."""
    w = np.asarray(w, float)
    t = (m * (T_fwd + T_back)
         + (w - 1) * 4 * hw.alpha
         + (w - 1) * (n / w) * 4 * hw.beta
         + (w - 1) * (n / w) * 2 * hw.gamma)
    return float(t) if t.ndim == 0 else t


def t_dh(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E):
    """Eq. (3): doubling-halving (power-of-two w).  Scalar or ndarray w."""
    t = (m * (T_fwd + T_back)
         + 4 * _log2(w) * hw.alpha
         + 4 * n * hw.beta
         + 2.5 * n * hw.gamma)
    return float(t) if t.ndim == 0 else t


def t_bb(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E):
    """Eq. (4): binary blocks (any w).  Scalar or ndarray w."""
    t = (m * (T_fwd + T_back)
         + (5 + 4 * np.ceil(_log2(w))) * hw.alpha
         + 7 * n * hw.beta
         + 3 * n * hw.gamma)
    return float(t) if t.ndim == 0 else t


def step_time(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E,
              algorithm: str | None = None) -> float:
    """Per-minibatch time with the algorithm Horovod would pick (§2.1)."""
    if algorithm is None:
        algorithm = best_algorithm(w, n)
    fn = {"ring": t_ring, "doubling_halving": t_dh, "binary_blocks": t_bb}
    return fn[algorithm](m, T_fwd, T_back, w, n, hw)


def step_time_table(m, T_fwd, T_back, ws, n,
                    hw: HardwareCoefficients = TPU_V5E,
                    threshold: float = 1e7) -> np.ndarray:
    """Vectorized ``step_time`` over an array of worker counts.

    Evaluates all three analytic models once over the whole array and
    selects per element with the ``best_algorithm`` rule (§2.1), so a
    full speed table costs three vectorized expressions instead of one
    Python-level dispatch per w.
    """
    ws = np.asarray(ws, float)
    wi = ws.astype(int)
    pow2 = (wi & (wi - 1)) == 0
    out = np.where(
        pow2,
        np.where(n <= threshold,
                 t_dh(m, T_fwd, T_back, ws, n, hw),
                 t_ring(m, T_fwd, T_back, ws, n, hw)),
        t_bb(m, T_fwd, T_back, ws, n, hw))
    return out


def simulated_step_time(m, T_fwd, T_back, w, n,
                        hw: HardwareCoefficients = TPU_V5E,
                        algorithm: str | None = None) -> float:
    """First-principles variant: α/β/γ counters from executing the actual
    schedule (repro.collectives.schedules) instead of the closed forms.
    Used to cross-validate eqs. (2)-(4)."""
    algorithm = algorithm or best_algorithm(w, n)
    # execute on a tiny vector; counters scale linearly in n
    probe = 64
    v = np.zeros((w, probe))
    _, st = ALGORITHMS[algorithm](v, itemsize=1)
    scale = n / probe
    comm = (st.steps * hw.alpha + st.bytes_sent * scale * hw.beta
            + st.bytes_reduced * scale * hw.gamma)
    return m * (T_fwd + T_back) + comm
