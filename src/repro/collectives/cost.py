"""Analytic per-minibatch time models — paper §3.2 eqs. (2)–(4) verbatim.

α: latency per message [s]; β: transfer time per byte [s/B];
γ: compute cost per vector byte [s/B]; n: model gradient size [bytes];
m: per-worker minibatch; w: workers.

``HardwareCoefficients`` maps the constants onto the TPU v5e target (ICI hop
latency / link bandwidth / VPU reduce throughput) — the functional form is
unchanged (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareCoefficients:
    alpha: float = 1e-6       # ICI hop latency ~1us
    beta: float = 1.0 / 45e9  # per-byte on a ~45GB/s effective ICI link
    gamma: float = 1.0 / 400e9  # VPU reduce bytes/s
    name: str = "tpu_v5e"


TPU_V5E = HardwareCoefficients()
# The paper's cluster: 100 Gbit/s (4x EDR) InfiniBand, K40m-era hosts.
INFINIBAND_100G = HardwareCoefficients(
    alpha=2e-6, beta=1.0 / 12.5e9, gamma=1.0 / 50e9, name="ib_100g")


def t_ring(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E):
    """Eq. (2): ring algorithm."""
    return (m * (T_fwd + T_back)
            + (w - 1) * 4 * hw.alpha
            + (w - 1) * (n / w) * 4 * hw.beta
            + (w - 1) * (n / w) * 2 * hw.gamma)


def t_dh(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E):
    """Eq. (3): doubling-halving (power-of-two w)."""
    lw = math.log2(w) if w > 1 else 0.0
    return (m * (T_fwd + T_back)
            + 4 * lw * hw.alpha
            + 4 * n * hw.beta
            + 2.5 * n * hw.gamma)


def t_bb(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E):
    """Eq. (4): binary blocks (any w)."""
    lw = math.ceil(math.log2(w)) if w > 1 else 0
    return (m * (T_fwd + T_back)
            + (5 + 4 * lw) * hw.alpha
            + 7 * n * hw.beta
            + 3 * n * hw.gamma)


def step_time(m, T_fwd, T_back, w, n, hw: HardwareCoefficients = TPU_V5E,
              algorithm: str | None = None) -> float:
    """Per-minibatch time with the algorithm Horovod would pick (§2.1)."""
    if algorithm is None:
        from repro.collectives.schedules import best_algorithm
        algorithm = best_algorithm(w, n)
    fn = {"ring": t_ring, "doubling_halving": t_dh, "binary_blocks": t_bb}
    return fn[algorithm](m, T_fwd, T_back, w, n, hw)


def simulated_step_time(m, T_fwd, T_back, w, n,
                        hw: HardwareCoefficients = TPU_V5E,
                        algorithm: str | None = None) -> float:
    """First-principles variant: α/β/γ counters from executing the actual
    schedule (repro.collectives.schedules) instead of the closed forms.
    Used to cross-validate eqs. (2)-(4)."""
    import numpy as np
    from repro.collectives.schedules import ALGORITHMS, best_algorithm
    algorithm = algorithm or best_algorithm(w, n)
    # execute on a tiny vector; counters scale linearly in n
    probe = 64
    v = np.zeros((w, probe))
    _, st = ALGORITHMS[algorithm](v, itemsize=1)
    scale = n / probe
    comm = (st.steps * hw.alpha + st.bytes_sent * scale * hw.beta
            + st.bytes_reduced * scale * hw.gamma)
    return m * (T_fwd + T_back) + comm
