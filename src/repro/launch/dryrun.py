import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (tests may scale the placeholder device count down via REPRO_DRYRUN_DEVICES
# *before* jax initializes; the production default above is 512.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) combo
on placeholder host devices, prove memory/sharding coherence, and extract
the roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

XLA's cost_analysis counts while-loop (scan) bodies ONCE, so raw numbers
undercount scanned layers.  Each combo therefore compiles three modules:
the production scan module (memory analysis + compile proof) and two small
UNROLLED depth variants (1 and 2 layer-units) whose cost delta gives the
true per-layer flops/bytes/collective bytes:

    total = cost(1 unit) + (units_full - 1) * (cost(2 units) - cost(1 unit))

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh single          # one combo
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun                # the full 40 x 2 sweep
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES
from repro.engine.steps import (make_train_step, make_prefill,
                                make_decode_step, train_state_specs)
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh, make_tiny_mesh
from repro.models import spec as pspec
from repro.models import layers as Lmod
from repro.models.layers import Sharder
from repro.models.registry import build_model, decode_window
from repro.optim.optimizers import adamw
from repro.sharding.rules import default_rules, tree_shardings

PROFILES: dict[str, dict] = {
    "baseline": {},
    # FSDP/ZeRO-3: additionally shard every weight's embed dim over "data";
    # GSPMD inserts per-layer all-gathers inside the scan (beyond-paper
    # optimization, EXPERIMENTS.md §Perf).
    "fsdp": {"embed": ("data",)},
    # padheads: mask-padded Q-heads up to the next multiple of the model
    # axis so attention shards by head instead of by head_dim (fixes the
    # 40-head/12-head all-reduce pathology); math-identical (see
    # tests/test_pad_heads.py).  Combines the rule table of baseline.
    "padheads": {},
    "padheads_fsdp": {"embed": ("data",)},
    # dponly: the paper's own regime — pure data parallelism, params
    # replicated, gradient exchange is THE collective (Horovod semantics).
    # The model axis idles; used to compare the paper's world against the
    # TP/FSDP production shardings in §Perf.
    "dponly": {"heads": (), "kv_heads": (), "mlp": (), "vocab": (),
               "experts": (), "ssm_heads": ()},
}


def apply_profile_cfg(cfg, profile: str):
    if profile.startswith("padheads") and cfg.n_heads % 16 != 0:
        import dataclasses as _dc
        return _dc.replace(cfg, pad_heads_to=-(-cfg.n_heads // 16) * 16)
    return cfg


def rules_for(kind: str, profile: str = "baseline"):
    table = dict(PROFILES[profile])
    if kind == "decode":
        # context-parallel cache: shard the cache sequence dim over whatever
        # axes the batch dim leaves free (long_500k: all of them)
        table["cache_seq"] = ("pod", "data", "model")
    return default_rules(table)


def with_depth(cfg, units: int):
    """Same-family config with ``units`` scan iterations."""
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=units * cfg.attn_every)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, n_layers=units,
                                   encoder_layers=units)
    return dataclasses.replace(cfg, n_layers=units)


def depth_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def build_jitted(cfg, shape, mesh, rules, *, window, microbatches: int = 1):
    """-> (jitted_fn, abstract_args). Shared by the main and cost passes."""
    model = build_model(cfg)
    sh = Sharder(mesh, rules)
    if shape.kind == "train":
        state_specs = train_state_specs(model, adamw())
        state_sh = tree_shardings(state_specs, mesh, rules)
        batch_specs = model.input_specs(shape)
        batch_sh = tree_shardings(batch_specs, mesh, rules)
        step = make_train_step(model, adamw(), sh, microbatches=microbatches)
        jitted = jax.jit(step,
                         in_shardings=(state_sh, batch_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=(state_sh, NamedSharding(mesh, P())),
                         donate_argnums=(0,))
        args = (pspec.abstract(state_specs), pspec.abstract(batch_specs),
                jax.ShapeDtypeStruct((), jnp.float32))
    elif shape.kind == "prefill":
        serve_specs = pspec.cast(model.param_specs(), jnp.bfloat16)
        params_sh = tree_shardings(serve_specs, mesh, rules)
        batch_specs = model.input_specs(shape)
        batch_sh = tree_shardings(batch_specs, mesh, rules)
        fn = make_prefill(model, sh, window=window)
        logits_spec = rules.spec_for(("batch", "seq", "vocab"),
                                     (shape.global_batch, shape.seq_len,
                                      cfg.vocab_size), mesh)
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                         out_shardings=NamedSharding(mesh, logits_spec))
        args = (pspec.abstract(serve_specs), pspec.abstract(batch_specs))
    else:  # decode
        serve_specs = pspec.cast(model.param_specs(), jnp.bfloat16)
        params_sh = tree_shardings(serve_specs, mesh, rules)
        cache_specs = model.cache_specs(shape)
        cache_sh = tree_shardings(cache_specs, mesh, rules)
        batch_specs = model.input_specs(shape)
        batch_sh = tree_shardings(batch_specs, mesh, rules)
        fn = make_decode_step(model, sh, window=window)
        logits_spec = rules.spec_for(("batch", "seq", "vocab"),
                                     (shape.global_batch, 1,
                                      cfg.vocab_size), mesh)
        jitted = jax.jit(fn,
                         in_shardings=(params_sh, cache_sh, batch_sh),
                         out_shardings=(NamedSharding(mesh, logits_spec),
                                        cache_sh),
                         donate_argnums=(1,))
        args = (pspec.abstract(serve_specs), pspec.abstract(cache_specs),
                pspec.abstract(batch_specs))
    return jitted, args


def _cost_record(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    colls = analysis.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": colls}


def corrected_costs(c1: dict, c2: dict, units_full: int) -> dict:
    """Scan-corrected totals from the 1-unit/2-unit unrolled cost records."""
    def tot(key):
        per = max(0.0, c2[key] - c1[key])
        return c1[key] + (units_full - 1) * per

    kinds = set(c1["coll"]) | set(c2["coll"])
    coll = {}
    for k in kinds:
        a, b = c1["coll"].get(k, 0.0), c2["coll"].get(k, 0.0)
        coll[k] = a + (units_full - 1) * max(0.0, b - a)
    return {"flops": tot("flops"), "bytes": tot("bytes"), "coll": coll,
            "per_layer_flops": max(0.0, c2["flops"] - c1["flops"]),
            "per_layer_bytes": max(0.0, c2["bytes"] - c1["bytes"])}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               tiny: bool = False, profile: str = "baseline",
               save_hlo: str | None = None, skip_costs: bool = False,
               rules=None, microbatches: int = 1) -> dict:
    cfg = apply_profile_cfg(get_config(arch), profile)
    shape = SHAPES[shape_name]
    mesh = (make_tiny_mesh(multi_pod=multi_pod) if tiny
            else make_production_mesh(multi_pod=multi_pod))
    n_dev = mesh.size
    rules = rules or rules_for(shape.kind, profile)
    window = decode_window(cfg, shape.seq_len)

    # ---- main compile: proof + memory analysis + raw costs ---------------
    # (cost variants below always use microbatches=1 — flop/byte totals are
    # microbatch-invariant, and the mb scan would hide them from
    # cost_analysis; the MAIN compile carries the memory effect.)
    t0 = time.perf_counter()
    jitted, args = build_jitted(cfg, shape, mesh, rules, window=window,
                                microbatches=microbatches)
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    ma = compiled.memory_analysis()
    raw = _cost_record(compiled)

    # ---- cost pass: unrolled 1/2-unit variants ---------------------------
    units = depth_units(cfg)
    if skip_costs:
        corr = dict(raw, per_layer_flops=None, per_layer_bytes=None)
    else:
        costs = []
        with Lmod.unroll_mode(True):
            for u in (1, 2):
                cfg_u = with_depth(cfg, u)
                j_u, a_u = build_jitted(cfg_u, shape, mesh, rules,
                                        window=window)
                costs.append(_cost_record(j_u.lower(*a_u).compile()))
        corr = corrected_costs(costs[0], costs[1], units)

    roof = analysis.Roofline(
        flops_per_device=corr["flops"], bytes_per_device=corr["bytes"],
        collective_bytes_per_device=float(sum(corr["coll"].values())),
        collectives=corr["coll"], n_devices=n_dev)

    model = build_model(cfg)
    n_total = pspec.n_params(model.param_specs())
    n_active = cfg.active_param_count() if cfg.is_moe else n_total
    mf = analysis.model_flops(cfg, shape, n_total, n_active)
    hlo_flops_total = roof.flops_per_device * n_dev
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tiny": tiny, "profile": profile, "n_devices": n_dev,
        "window": window, "scan_units": units,
        "params_total": int(n_total), "params_active": int(n_active),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "roofline": roof.as_dict(),
        "raw_costs_scan_body_once": raw,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_flops_total
                               if hlo_flops_total else None),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
    return rec


def format_line(rec: dict) -> str:
    r = rec["roofline"]
    return (f"mem/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
            f"compute={r['compute_s']*1e3:.2f}ms "
            f"memory={r['memory_s']*1e3:.2f}ms "
            f"coll={r['collective_s']*1e3:.2f}ms "
            f"dom={r['dominant']} "
            f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'], 3)} "
            f"compile={rec['compile_s']:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--profile", default="baseline", choices=list(PROFILES))
    ap.add_argument("--tiny", action="store_true",
                    help="8-device test mesh (set REPRO_DRYRUN_DEVICES=8)")
    ap.add_argument("--skip-costs", action="store_true",
                    help="main compile only (no unrolled cost variants)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches (train shapes)")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip combos whose JSON record already exists")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.out:
        os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                prof = args.profile + (f"_mb{args.microbatches}"
                                       if args.microbatches > 1 else "")
                tag = (f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
                       f"|{prof}")
                if args.out and args.skip_existing:
                    fn = tag.replace("|", "__").replace(".", "_") + ".json"
                    if os.path.exists(os.path.join(args.out, fn)):
                        print(f"SKIP {tag} (exists)", flush=True)
                        continue
                try:
                    t0 = time.perf_counter()
                    rec = dryrun_one(arch, shape, multi_pod=mp,
                                     tiny=args.tiny, profile=args.profile,
                                     save_hlo=args.save_hlo,
                                     skip_costs=args.skip_costs,
                                     microbatches=args.microbatches)
                    rec["profile"] = prof
                    rec["total_s"] = time.perf_counter() - t0
                    print(f"OK   {tag} {format_line(rec)} "
                          f"total={rec['total_s']:.0f}s", flush=True)
                    if args.out:
                        fn = tag.replace("|", "__").replace(".", "_") + ".json"
                        with open(os.path.join(args.out, fn), "w") as f:
                            json.dump(rec, f, indent=1)
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
