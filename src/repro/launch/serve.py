"""Batched serving driver: prefill a prompt batch, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.shapes import InputShape
from repro.data.synthetic import TokenStream
from repro.models import spec as pspec
from repro.models.registry import build_model, decode_window


def serve(cfg, *, batch: int, prompt_len: int, new_tokens: int,
          params=None, greedy: bool = True, log: bool = True):
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    cache_len = prompt_len + new_tokens
    shape = InputShape("serve", cache_len, batch, "decode")
    cache = pspec.init_params(jax.random.PRNGKey(1), model.cache_specs(shape))
    window = decode_window(cfg, cache_len)

    data = TokenStream(cfg.vocab_size, prompt_len, seed=3)
    prompts = jnp.asarray(data.batch(0, batch)["tokens"])      # [B, P]

    decode = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b, window=window))

    # prefill by stepping the decoder over the prompt (cache-building path;
    # the chunked prefill fast path is exercised by model.prefill in tests)
    t0 = time.perf_counter()
    tok = prompts[:, 0:1]
    out_tokens = [tok]
    for t in range(cache_len - 1):
        batch_t = {"tokens": tok,
                   "pos": jnp.full((batch,), t, jnp.int32)}
        logits, cache = decode(params, cache, batch_t)
        if t + 1 < prompt_len:
            tok = prompts[:, t + 1:t + 2]       # teacher-forced prompt
        else:
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
            out_tokens.append(tok)
        if len(out_tokens) - 1 >= new_tokens:
            break
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens[1:], axis=1)
    if log:
        print(f"generated {gen.shape} in {dt:.2f}s "
              f"({batch * new_tokens / dt:.1f} tok/s)")
    return np.asarray(gen), dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    gen, dt = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                    new_tokens=args.new_tokens)
    print("sample:", gen[0][:16])


if __name__ == "__main__":
    main()
