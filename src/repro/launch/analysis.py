"""Roofline analysis from compiled dry-run artifacts.

cost_analysis()/memory_analysis() report PER-DEVICE flops and bytes on the
SPMD-partitioned module, so the three roofline terms are:

    compute    = flops_per_device / peak_flops_per_chip
    memory     = bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / link_bw

collective bytes are parsed from the partitioned HLO text: the output-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (cost_analysis does not break these out).
Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes by collective kind, from partitioned HLO."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if kind.endswith("-done"):
            continue  # avoid double counting async pairs
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: dict
    n_devices: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collectives": self.collectives,
            "n_devices": self.n_devices,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(compiled, n_devices: int) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    colls = collective_bytes(txt)
    return Roofline(flops_per_device=flops, bytes_per_device=byts,
                    collective_bytes_per_device=float(sum(colls.values())),
                    collectives=colls, n_devices=n_devices)


def model_flops(cfg, shape, n_params_total: int, n_params_active: int
                ) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for
    inference forward (decode: D = new tokens)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.global_batch  # decode: 1 token/seq
