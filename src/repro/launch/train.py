"""End-to-end training driver.

Trains any ``--arch`` (full or ``--smoke`` reduced config) on the synthetic
token pipeline with AdamW + warmup-cosine, checkpointing through the elastic
store.  ``--workers`` sets the data-parallel worker count the scheduler
allocated: per-worker batch m stays fixed, global batch = m * workers, LR
linearly rescaled (paper eq. 7).  With multiple real devices and
``--grad-exchange ring|doubling_halving`` the gradient exchange runs the
paper's explicit algorithm under shard_map instead of implicit GSPMD psum.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 100 --workers 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.checkpoint.store import CheckpointStore
from repro.data.synthetic import TokenStream
from repro.engine.steps import make_train_step, init_train_state
from repro.models.registry import build_model
from repro.optim.optimizers import adamw
from repro.optim.schedule import warmup_cosine, rescale_lr


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--m-per-worker", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4,
                    help="base LR at 1 worker (eq. 7 scales it)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-exchange", default=None,
                    choices=[None, "ring", "doubling_halving"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt = adamw()
    data = TokenStream(cfg.vocab_size, args.seq, seed=0)
    global_batch = args.m_per_worker * args.workers
    base_lr = rescale_lr(args.lr, args.workers, 1)
    sched = warmup_cosine(base_lr, warmup=min(20, args.steps // 5 + 1),
                          total=args.steps)

    n_dev = jax.device_count()
    if args.grad_exchange and n_dev > 1:
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(n_dev)
        step_fn = make_train_step(model, opt,
                                  grad_exchange=args.grad_exchange)
        jitted = jax.jit(jax.shard_map(
            step_fn, mesh=mesh,
            in_specs=(P(), {"tokens": P("data"), "labels": P("data")}, P()),
            out_specs=(P(), P()), check_vma=False))
    else:
        jitted = jax.jit(make_train_step(model, opt))

    state = init_train_state(model, opt)
    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    step0 = 0
    if store and args.resume and store.latest_step() is not None:
        state, meta, secs = store.restore(state)
        step0 = store.latest_step()
        print(f"restored step {step0} in {secs:.2f}s (meta={meta})")

    t0 = time.perf_counter()
    first_loss = None
    for i in range(step0, step0 + args.steps):
        batch = data.batch(i, global_batch)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, loss = jitted(state, batch, jnp.float32(sched(i)))
        if first_loss is None:
            first_loss = float(loss)
        if i % args.log_every == 0 or i == step0 + args.steps - 1:
            dt = time.perf_counter() - t0
            tok_s = (i - step0 + 1) * global_batch * args.seq / max(dt, 1e-9)
            print(f"step {i:5d} loss {float(loss):.4f} lr {sched(i):.2e} "
                  f"tok/s {tok_s:,.0f}", flush=True)
    if store:
        secs = store.save(step0 + args.steps, state,
                          meta={"workers": args.workers})
        print(f"checkpointed step {step0 + args.steps} in {secs:.2f}s")
    return first_loss, float(loss)


if __name__ == "__main__":
    main()
