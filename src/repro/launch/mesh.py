"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: 16x16 = 256 chips (TPU v5e pod, data x model).
Multi-pod: 2 x 16 x 16 = 512 chips with a leading "pod" axis (data
parallelism across pods over DCN/ICI).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_tiny_mesh(*, multi_pod: bool = False):
    """Scaled-down mesh (8 host devices) for CI subprocess tests."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_data_mesh(n: int):
    """Pure data-parallel mesh of n devices (elastic trainer segments)."""
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
