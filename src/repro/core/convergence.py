"""Online convergence modelling — paper §3.1, eq. (1).

SGD converges at O(1/k), so loss is fitted as

    l = 1 / (beta0 * k + beta1) + beta2,   beta0 > 0

by NNLS: for a grid of beta2 candidates, 1/(l - beta2) = beta0*k + beta1 is
linear, solved with non-negative least squares (own Lawson–Hanson-style
projected solver; scipy.optimize.nnls is only used as a cross-check in
tests).  The fitted curve predicts the step/epoch at which the loss reaches
the convergence target, i.e. the remaining epochs Q_j the scheduler needs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def nnls(A: np.ndarray, b: np.ndarray, iters: int = 3000,
         tol: float = 1e-12) -> np.ndarray:
    """Projected-gradient NNLS: min ||Ax - b||^2 s.t. x >= 0."""
    A = np.asarray(A, float)
    b = np.asarray(b, float)
    AtA = A.T @ A
    Atb = A.T @ b
    lip = np.linalg.norm(AtA, 2) + 1e-12
    x = np.maximum(0.0, np.linalg.lstsq(A, b, rcond=None)[0])
    step = 1.0 / lip
    for _ in range(iters):
        g = AtA @ x - Atb
        x_new = np.maximum(0.0, x - step * g)
        if np.max(np.abs(x_new - x)) < tol:
            x = x_new
            break
        x = x_new
    return x


@dataclasses.dataclass(frozen=True)
class ConvergenceModel:
    beta0: float
    beta1: float
    beta2: float

    def loss_at(self, k):
        k = np.asarray(k, float)
        return 1.0 / (self.beta0 * k + self.beta1) + self.beta2

    def steps_to_loss(self, target: float) -> float:
        """Smallest k with predicted loss <= target (inf if unreachable)."""
        if target <= self.beta2 or self.beta0 <= 0:
            return np.inf
        return max(0.0, (1.0 / (target - self.beta2) - self.beta1)
                   / self.beta0)


def fit_convergence(steps: np.ndarray, losses: np.ndarray,
                    n_beta2: int = 64) -> ConvergenceModel:
    """Fit eq. (1) by NNLS over a beta2 grid (the transform trick)."""
    steps = np.asarray(steps, float)
    losses = np.asarray(losses, float)
    assert steps.shape == losses.shape and steps.size >= 3
    lmin = float(losses.min())
    best, best_err = None, np.inf
    for beta2 in np.linspace(0.0, max(0.0, lmin - 1e-3), n_beta2):
        y = 1.0 / np.maximum(losses - beta2, 1e-9)
        A = np.stack([steps, np.ones_like(steps)], axis=1)
        coef = nnls(A, y)
        model = ConvergenceModel(float(coef[0]), float(coef[1]), float(beta2))
        err = float(np.mean((model.loss_at(steps) - losses) ** 2))
        if err < best_err and coef[0] > 0:
            best, best_err = model, err
    if best is None:  # degenerate (flat loss): fall back to tiny slope
        best = ConvergenceModel(1e-9, 1.0 / max(losses.mean(), 1e-9), 0.0)
    return best
