"""Resource allocation — paper §4.

The problem (§4.1):   min Σ_j t_j,  t_j = Q_j / f_j(w_j),
                      Σ_j w_j <= C,  w_j in Z+           (NP-hard, non-convex)

Solvers:
  * ``doubling_heuristic``  — §4.2, the paper's contribution: start every job
    at 1 worker, repeatedly *double* the job with the best average marginal
    gain (Q/f(w) - Q/f(2w)) / w.  Doubling steps over the power-of-two
    cliff (8 -> 9 is a per-GPU regression under doubling-halving; 8 -> 16 is
    not), where +1 greedy stalls.
  * ``optimus_greedy``      — the Optimus baseline: +1 worker at a time.
  * ``exact_dp``            — exact DP over worker counts (validation).
  * ``fixed``               — every job requests a constant w (§7 baselines).

Three API layers, one semantics:

  * *SoA* (``doubling_heuristic_soa`` / ``fixed_soa``) take the simulator's
    structure-of-arrays state directly — a remaining-work ndarray plus a 2-D
    speed-table ndarray — and return an int64 allocation array aligned with
    the input, so the event loop never materializes per-job tuples.  Initial
    w=1 gains are one vectorized pass; the doubling loop is the same lazy
    max-heap as the table layer.
  * *Table-driven* (``doubling_heuristic_table`` & friends) take jobs as
    (job_id, Q, speed_table) where ``speed_table[w]`` is f(w) for
    w = 0..max index.  These are the hot path: gains come from O(1) array
    lookups, and the doubling/greedy loops pop a lazy max-heap instead of
    rescanning all J jobs per step.  A job's marginal gain depends only on
    its own (Q, w), so heap entries never need recomputation: an entry is
    pushed when the job reaches w and is simply discarded as stale if the
    job's allocation has moved on by the time it is popped.
  * *Callable-based* (``doubling_heuristic`` & friends) keep the original
    (job_id, Q, speed_fn) signature as thin adapters: they sample the
    callable once into a table and delegate.  Allocation-for-allocation
    identical to the pre-table implementations (the ``*_ref`` versions
    kept below for parity tests and benchmarks).

Tie-breaking matches the original scan exactly: among equal best gains the
job earliest in the input sequence wins, which the heap encodes by ordering
entries (-gain, input_index).
"""
from __future__ import annotations

import heapq
import math
from typing import Callable, Sequence

import numpy as np

Alloc = dict[int, int]
JobTuple = tuple[int, float, Callable[[int], float]]  # (id, Q, speed_fn)
# (id, Q, speed_table) with speed_table[w] = f(w), index 0 unused (= 0.0);
# any indexable works, but a plain list avoids ndarray-scalar overhead
TableJobTuple = tuple[int, float, Sequence[float]]


def _gain_double(Q: float, f, w: int) -> float:
    """Average marginal gain of doubling w -> 2w, per added GPU (eq. 6)."""
    t_now = Q / max(f(w), 1e-12)
    t_next = Q / max(f(2 * w), 1e-12)
    return (t_now - t_next) / w


def _gain_double_table(Q: float, table, w: int) -> float:
    """Eq. 6 gain from a speed table — same float ops as ``_gain_double``."""
    t_now = Q / max(table[w], 1e-12)
    t_next = Q / max(table[2 * w], 1e-12)
    return (t_now - t_next) / w


def _table_bound(capacity: int, max_w) -> int:
    """Largest w any solver ever evaluates: min(max_w, capacity).

    Doubling only scores w -> 2w when the extra w workers still fit
    (used + w <= capacity with used >= w, so 2w <= capacity) and
    2w <= max_w; +1 greedy only scores w+1 <= capacity and <= max_w.
    With per-job caps the bound is the largest cap in the fleet.
    """
    if max_w is None:
        return capacity
    if hasattr(max_w, "__len__"):
        return min(max(max_w) if len(max_w) else capacity, capacity)
    return min(max_w, capacity)


def _caps(max_w, n: int) -> list:
    """Normalize ``max_w`` to one cap per job.

    The doubling solvers accept ``max_w`` as None (unbounded), a scalar
    (every job shares the cap — the paper's single-node-fleet setup), or a
    sequence/ndarray of per-job caps aligned with the job order
    (heterogeneous fleets, e.g. the ``mixed_maxw`` workload pattern).
    """
    if hasattr(max_w, "__len__"):
        caps = list(max_w)
        assert len(caps) == n, f"per-job max_w length {len(caps)} != {n}"
        return caps
    return [max_w] * n


def _sample_table(f: Callable[[int], float], max_index: int) -> list[float]:
    return [0.0] + [f(w) for w in range(1, max_index + 1)]


def doubling_heuristic_table(jobs: Sequence[TableJobTuple], capacity: int,
                             max_w=None) -> Alloc:
    """§4.2 doubling heuristic over precomputed speed tables.

    Lazy max-heap over doubling gains: O((J + doublings) log J) instead of
    the reference implementation's O(J) rescan per doubling step.
    ``max_w`` may be a scalar or per-job caps (see ``_caps``).
    """
    jobs = list(jobs)
    caps = _caps(max_w, len(jobs))
    alloc: Alloc = {}
    used = 0
    heap: list[tuple[float, int, int]] = []   # (-gain, input index, w)
    for idx, (jid, Q, table) in enumerate(jobs):
        if used < capacity:
            alloc[jid] = 1
            used += 1
            mw = caps[idx]
            if (mw is None or 2 <= mw) and 2 < len(table):
                g = _gain_double_table(Q, table, 1)
                if g > 0.0:
                    heap.append((-g, idx, 1))
        else:
            alloc[jid] = 0
    heapq.heapify(heap)
    while heap:
        neg_g, idx, w = heapq.heappop(heap)
        jid, Q, table = jobs[idx]
        if alloc[jid] != w:
            continue                      # stale: job already doubled past w
        if used + w > capacity:
            continue    # never feasible again (used only grows) -> discard
        used += w
        w2 = 2 * w
        alloc[jid] = w2
        mw = caps[idx]
        if ((mw is None or 2 * w2 <= mw) and used + w2 <= capacity
                and 2 * w2 < len(table)):
            g = _gain_double_table(Q, table, w2)
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w2))
    return alloc


def doubling_heuristic_soa(Q, tables, capacity: int,
                           max_w=None, rows=None):
    """§4.2 doubling heuristic over structure-of-arrays job state.

    The SoA twin of ``doubling_heuristic_table`` for the simulator hot
    path: ``Q`` is a float ndarray of remaining work (one entry per job,
    in allocation order), ``tables`` a 2-D ndarray whose row ``rows[i]``
    is job i's speed table (``rows=None`` means row i), and the result is
    an int64 ndarray of worker counts aligned with ``Q`` — no per-job
    tuples or dicts are materialized.  The initial w=1 gains are computed
    in one vectorized pass; the doubling loop is the same lazy max-heap
    with ``(-gain, input index, w)`` entries, so allocations (and
    tie-breaks) are bit-identical to the table/reference solvers.

    Inside the doubling loop everything is plain Python ints/floats
    (ndarray-scalar indexing would triple the per-pop cost); ``float`` /
    ``.tolist()`` conversions of float64 values are exact, so this costs
    nothing in identity.
    """
    n = len(Q)
    row_of = list(range(n)) if rows is None else rows.tolist()
    caps = _caps(max_w, n)
    out = [0] * n
    n1 = min(n, capacity)
    out[:n1] = [1] * n1
    used = n1
    W = tables.shape[1] - 1
    heap: list[tuple[float, int, int]] = []
    if n1 and 2 <= W:
        head = row_of[:n1]
        t_now = Q[:n1] / np.maximum(tables[head, 1], 1e-12)
        t_next = Q[:n1] / np.maximum(tables[head, 2], 1e-12)
        # gain per added GPU at w=1 (÷1 exact)
        gains = (t_now - t_next).tolist()
        heap = [(-g, i, 1) for i, g in enumerate(gains)
                if g > 0.0 and (caps[i] is None or 2 <= caps[i])]
        heapq.heapify(heap)
    q_of = Q.tolist()
    while heap:
        neg_g, idx, w = heapq.heappop(heap)
        if out[idx] != w:
            continue                      # stale: job already doubled past w
        if used + w > capacity:
            continue    # never feasible again (used only grows) -> discard
        used += w
        w2 = 2 * w
        out[idx] = w2
        mw = caps[idx]
        if ((mw is None or 2 * w2 <= mw) and used + w2 <= capacity
                and 2 * w2 <= W):
            table = tables[row_of[idx]]
            gq = q_of[idx]
            g = (gq / max(float(table[w2]), 1e-12)
                 - gq / max(float(table[2 * w2]), 1e-12)) / w2
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w2))
    return np.asarray(out, dtype=np.int64)


def fixed_soa(n: int, capacity: int, w_fixed: int):
    """SoA twin of ``fixed``: first ``capacity // w_fixed`` jobs get the
    all-or-nothing gang of ``w_fixed`` (FIFO), the rest get 0."""
    out = np.zeros(n, dtype=np.int64)
    out[:min(n, capacity // w_fixed)] = w_fixed
    return out


def optimus_greedy_table(jobs: Sequence[TableJobTuple], capacity: int,
                         max_w: int | None = None) -> Alloc:
    """Optimus [8] over precomputed speed tables, with a lazy max-heap."""
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    heap: list[tuple[float, int, int]] = []   # (-gain, input index, w)

    def entry(idx: int, Q: float, table, w: int):
        """Heap entry for the +1 gain at w, or None if never selectable."""
        if max_w is not None and w + 1 > max_w:
            return None
        if w + 1 >= len(table):
            return None    # beyond the table bound => capacity-infeasible
        g = Q / max(table[w], 1e-12) - Q / max(table[w + 1], 1e-12)
        return (-g, idx, w) if g > 0.0 else None

    for idx, (jid, Q, table) in enumerate(jobs):
        if used < capacity:
            alloc[jid] = 1
            used += 1
            e = entry(idx, Q, table, 1)
            if e is not None:
                heap.append(e)
        else:
            alloc[jid] = 0
    heapq.heapify(heap)
    while used < capacity and heap:
        neg_g, idx, w = heapq.heappop(heap)
        jid, Q, table = jobs[idx]
        if alloc[jid] != w:
            continue                                   # stale entry
        alloc[jid] = w + 1
        used += 1
        e = entry(idx, Q, table, w + 1)
        if e is not None:
            heapq.heappush(heap, e)
    return alloc


def exact_dp_table(jobs: Sequence[TableJobTuple], capacity: int,
                   max_w: int | None = None,
                   powers_of_two: bool = False) -> Alloc:
    """Exact minimizer of Σ Q_j / f_j(w_j) by DP over capacity, from tables.

    Same DP (and identical tie-breaking) as the callable version; per-job
    costs Q/f(w) are precomputed once per job instead of re-evaluating the
    speed model in the O(J * C * W) inner loop.
    """
    jobs = list(jobs)
    J = len(jobs)
    wmax = min(max_w or capacity, capacity)
    choices = ([2 ** k for k in range(int(math.log2(wmax)) + 1)]
               if powers_of_two else list(range(1, wmax + 1)))
    assert J <= capacity, "exact_dp assumes every job can get >=1 worker (Z+)"
    dp = {0: (0.0, ())}
    for (jid, Q, table) in jobs:
        costs = [Q / max(table[w], 1e-12) for w in choices]
        ndp: dict[int, tuple[float, tuple]] = {}
        for c, (cost, chosen) in dp.items():
            for w, t in zip(choices, costs):
                nc = c + w
                if nc > capacity:
                    continue
                cand = (cost + t, chosen + (w,))
                if nc not in ndp or cand[0] < ndp[nc][0]:
                    ndp[nc] = cand
        dp = ndp
    best_cost, best_alloc = min(dp.values(), key=lambda kv: kv[0])
    return {jid: w for (jid, _, _), w in zip(jobs, best_alloc)}


# --------------------------------------------------------------------------
# Callable-based API: thin adapters over the table solvers.
# --------------------------------------------------------------------------

def doubling_heuristic(jobs: Sequence[JobTuple], capacity: int,
                       max_w=None) -> Alloc:
    bound = _table_bound(capacity, max_w)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return doubling_heuristic_table(tjobs, capacity, max_w)


def optimus_greedy(jobs: Sequence[JobTuple], capacity: int,
                   max_w: int | None = None) -> Alloc:
    bound = _table_bound(capacity, max_w)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return optimus_greedy_table(tjobs, capacity, max_w)


def exact_dp(jobs: Sequence[JobTuple], capacity: int,
             max_w: int | None = None, powers_of_two: bool = False) -> Alloc:
    # the DP normalizes with `max_w or capacity` (0 means unbounded, seed
    # semantics), so the sampled table must use the same bound
    bound = min(max_w or capacity, capacity)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return exact_dp_table(tjobs, capacity, max_w, powers_of_two)


def fixed(jobs: Sequence[JobTuple], capacity: int, w_fixed: int) -> Alloc:
    """Every job requests w_fixed GPUs, granted FIFO while capacity lasts."""
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        w = min(w_fixed, capacity - used)
        w = w if w == w_fixed else 0    # all-or-nothing gang allocation
        alloc[jid] = w
        used += w
    return alloc


def total_time(jobs: Sequence[JobTuple], alloc: Alloc) -> float:
    tot = 0.0
    for (jid, Q, f) in jobs:
        w = alloc.get(jid, 0)
        if w > 0:
            tot += Q / max(f(w), 1e-12)
    return tot


# --------------------------------------------------------------------------
# Reference implementations — the pre-table O(J)-rescan solvers, kept with
# the seed's cost profile for allocation-parity tests and as the "seed"
# side of benchmarks/bench_scheduler.py speedup measurements.  (The only
# change since the seed: ``doubling_heuristic_ref`` accepts per-job caps
# via ``_caps``, extended in lockstep with the fast solvers so parity
# stays meaningful on heterogeneous fleets.)
# --------------------------------------------------------------------------

def doubling_heuristic_ref(jobs: Sequence[JobTuple], capacity: int,
                           max_w=None) -> Alloc:
    jobs = list(jobs)
    caps = _caps(max_w, len(jobs))   # scalar or per-job, like the fast path
    alloc: Alloc = {}
    used = 0
    # 1 worker to every job (FIFO when oversubscribed)
    for (jid, _, _) in jobs:
        if used < capacity:
            alloc[jid] = 1
            used += 1
        else:
            alloc[jid] = 0
    # doubling by best average marginal gain
    while True:
        best, best_gain = None, 0.0
        for idx, (jid, Q, f) in enumerate(jobs):
            w = alloc[jid]
            if w == 0:
                continue
            mw = caps[idx]
            if mw is not None and 2 * w > mw:
                continue
            if used + w > capacity:   # doubling adds w more workers
                continue
            g = _gain_double(Q, f, w)
            if g > best_gain:
                best, best_gain = jid, g
        if best is None:
            return alloc
        used += alloc[best]
        alloc[best] *= 2


def optimus_greedy_ref(jobs: Sequence[JobTuple], capacity: int,
                       max_w: int | None = None) -> Alloc:
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        if used < capacity:
            alloc[jid] = 1
            used += 1
        else:
            alloc[jid] = 0
    while used < capacity:
        best, best_gain = None, 0.0
        for (jid, Q, f) in jobs:
            w = alloc[jid]
            if w == 0:
                continue
            if max_w is not None and w + 1 > max_w:
                continue
            g = Q / max(f(w), 1e-12) - Q / max(f(w + 1), 1e-12)
            if g > best_gain:
                best, best_gain = jid, g
        if best is None:
            return alloc
        alloc[best] += 1
        used += 1
    return alloc


def exact_dp_ref(jobs: Sequence[JobTuple], capacity: int,
                 max_w: int | None = None,
                 powers_of_two: bool = False) -> Alloc:
    jobs = list(jobs)
    J = len(jobs)
    wmax = min(max_w or capacity, capacity)
    choices = ([2 ** k for k in range(int(math.log2(wmax)) + 1)]
               if powers_of_two else list(range(1, wmax + 1)))
    assert J <= capacity, "exact_dp assumes every job can get >=1 worker (Z+)"
    dp = {0: (0.0, ())}
    for (jid, Q, f) in jobs:
        ndp: dict[int, tuple[float, tuple]] = {}
        for c, (cost, chosen) in dp.items():
            for w in choices:
                nc = c + w
                if nc > capacity:
                    continue
                t = 0.0 if w == 0 else Q / max(f(w), 1e-12)
                cand = (cost + t, chosen + (w,))
                if nc not in ndp or cand[0] < ndp[nc][0]:
                    ndp[nc] = cand
        dp = ndp
    best_cost, best_alloc = min(dp.values(), key=lambda kv: kv[0])
    return {jid: w for (jid, _, _), w in zip(jobs, best_alloc)}
