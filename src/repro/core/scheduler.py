"""Resource allocation — paper §4 — and the scheduling-policy registry.

The problem (§4.1):   min Σ_j t_j,  t_j = Q_j / f_j(w_j),
                      Σ_j w_j <= C,  w_j in Z+           (NP-hard, non-convex)

Solvers:
  * ``doubling_heuristic``  — §4.2, the paper's contribution: start every job
    at 1 worker, repeatedly *double* the job with the best average marginal
    gain (Q/f(w) - Q/f(2w)) / w.  Doubling steps over the power-of-two
    cliff (8 -> 9 is a per-GPU regression under doubling-halving; 8 -> 16 is
    not), where +1 greedy stalls.
  * ``optimus_greedy``      — the Optimus baseline: +1 worker at a time.
  * ``exact_dp``            — exact DP over worker counts (validation).
  * ``fixed``               — every job requests a constant w (§7 baselines).

Solver API layers, one semantics:

  * *SoA* (``doubling_heuristic_soa`` / ``optimus_greedy_soa`` /
    ``fixed_soa``) take the simulator's structure-of-arrays state directly
    — a remaining-work ndarray plus a 2-D speed-table ndarray — and return
    an int64 allocation array aligned with the input, so the event loop
    never materializes per-job tuples.  Initial w=1 gains are one
    vectorized pass over the ``min(n, capacity)`` candidate prefix (the
    only jobs a FIFO-seeded solver can ever grant workers); the
    doubling/+1 loop is the same lazy max-heap as the table layer.
  * *Incremental* (``_PersistentDoublingHeap`` / ``_PersistentOptimusHeap``
    / ``_PersistentSRTFHeap``, engaged automatically when the fast engine's
    :class:`IncrementalContext` rides on the view) carry the gain-heap /
    remaining-time order *across* reallocation ticks, keyed by a
    generation-stamped admission sequence: a tick pushes entries only for
    jobs whose remaining work moved (arrivals, jobs that ran) and lazily
    discards entries for completed or re-stamped jobs — O(Δ log J) per
    tick instead of an O(J) rebuild, allocation-for-allocation identical
    to the fresh solvers (fuzz-, property- and trace-gated).
  * *Table-driven* (``doubling_heuristic_table`` & friends) take jobs as
    (job_id, Q, speed_table) where ``speed_table[w]`` is f(w) for
    w = 0..max index.  Gains come from O(1) array lookups, and the
    doubling/greedy loops pop a lazy max-heap instead of rescanning all J
    jobs per step.  A job's marginal gain depends only on its own (Q, w),
    so heap entries never need recomputation: an entry is pushed when the
    job reaches w and is simply discarded as stale if the job's allocation
    has moved on by the time it is popped.
  * *Callable-based* (``doubling_heuristic`` & friends) keep the original
    (job_id, Q, speed_fn) signature as thin adapters: they sample the
    callable once into a table and delegate.  Allocation-for-allocation
    identical to the pre-table implementations (the ``*_ref`` seed
    versions now live in ``repro.core._reference``, used only by parity
    tests and ``benchmarks/bench_scheduler.py``).

Tie-breaking matches the original scan exactly: among equal best gains the
job earliest in the input sequence wins, which the heap encodes by ordering
entries (-gain, input_index).

On top of the solvers sits the **policy registry** (bottom of this
module): every cluster strategy — the paper's ``precompute`` /
``exploratory`` / ``fixed_k`` plus SRTF, the Optimus +1-greedy and the
GADGET-style utility greedy — is a :class:`SchedulingPolicy` with one
``allocate(state, cluster, now)`` entry point over the SoA views
(:class:`AllocView`).  Both simulator engines, the benchmarks and the
tests construct policies exclusively through :func:`get_policy`, so a new
strategy is one registered class — not three parallel solver stacks.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Sequence

import numpy as np

from repro.collectives.cost import ClusterModel

Alloc = dict[int, int]
JobTuple = tuple[int, float, Callable[[int], float]]  # (id, Q, speed_fn)
# (id, Q, speed_table) with speed_table[w] = f(w), index 0 unused (= 0.0);
# any indexable works, but a plain list avoids ndarray-scalar overhead
TableJobTuple = tuple[int, float, Sequence[float]]


def _gain_double(Q: float, f, w: int) -> float:
    """Average marginal gain of doubling w -> 2w, per added GPU (eq. 6)."""
    t_now = Q / max(f(w), 1e-12)
    t_next = Q / max(f(2 * w), 1e-12)
    return (t_now - t_next) / w


def _gain_double_table(Q: float, table, w: int) -> float:
    """Eq. 6 gain from a speed table — same float ops as ``_gain_double``."""
    t_now = Q / max(table[w], 1e-12)
    t_next = Q / max(table[2 * w], 1e-12)
    return (t_now - t_next) / w


def _table_bound(capacity: int, max_w) -> int:
    """Largest w any solver ever evaluates: min(max_w, capacity).

    Doubling only scores w -> 2w when the extra w workers still fit
    (used + w <= capacity with used >= w, so 2w <= capacity) and
    2w <= max_w; +1 greedy only scores w+1 <= capacity and <= max_w.
    With per-job caps the bound is the largest cap in the fleet.
    """
    if max_w is None:
        return capacity
    if hasattr(max_w, "__len__"):
        return min(max(max_w) if len(max_w) else capacity, capacity)
    return min(max_w, capacity)


def _caps(max_w, n: int) -> list:
    """Normalize ``max_w`` to one cap per job.

    The doubling solvers accept ``max_w`` as None (unbounded), a scalar
    (every job shares the cap — the paper's single-node-fleet setup), or a
    sequence/ndarray of per-job caps aligned with the job order
    (heterogeneous fleets, e.g. the ``mixed_maxw`` workload pattern).
    """
    if hasattr(max_w, "__len__"):
        caps = list(max_w)
        assert len(caps) == n, f"per-job max_w length {len(caps)} != {n}"
        return caps
    return [max_w] * n


def _caps_head(max_w, n: int, n1: int) -> list:
    """``_caps`` for the first ``n1`` jobs only — the SoA solvers never
    grant workers past the ``min(n, capacity)`` prefix, so the rest of a
    per-job cap array is never read."""
    if hasattr(max_w, "__len__"):
        assert len(max_w) == n, f"per-job max_w length {len(max_w)} != {n}"
        head = max_w[:n1]
        return head.tolist() if isinstance(head, np.ndarray) else list(head)
    return [max_w] * n1


def _gains_w1(Q, tables, rows) -> list[float]:
    """Vectorized w=1 gain pass shared by the fresh SoA solvers and the
    persistent heaps' refresh: per added GPU, (Q/f(1) - Q/f(2)) / 1 —
    identical for the doubling and +1 step rules at w=1, and elementwise
    (the same float values regardless of which jobs share the vector)."""
    t_now = Q / np.maximum(tables[rows, 1], 1e-12)
    t_next = Q / np.maximum(tables[rows, 2], 1e-12)
    return (t_now - t_next).tolist()


def _grow_array(arr: np.ndarray, m: int, fill) -> np.ndarray:
    """``arr`` doubled (repeatedly) to hold at least ``m`` entries, new
    slots set to ``fill`` — the one growth pattern every per-seq array in
    this module shares."""
    cap = len(arr)
    if m <= cap:
        return arr
    while cap < m:
        cap *= 2
    new = np.full(cap, fill, arr.dtype)
    new[:len(arr)] = arr
    return new


def _sample_table(f: Callable[[int], float], max_index: int) -> list[float]:
    return [0.0] + [f(w) for w in range(1, max_index + 1)]


def doubling_heuristic_table(jobs: Sequence[TableJobTuple], capacity: int,
                             max_w=None) -> Alloc:
    """§4.2 doubling heuristic over precomputed speed tables.

    Lazy max-heap over doubling gains: O((J + doublings) log J) instead of
    the reference implementation's O(J) rescan per doubling step.
    ``max_w`` may be a scalar or per-job caps (see ``_caps``).
    """
    jobs = list(jobs)
    caps = _caps(max_w, len(jobs))
    alloc: Alloc = {}
    used = 0
    heap: list[tuple[float, int, int]] = []   # (-gain, input index, w)
    for idx, (jid, Q, table) in enumerate(jobs):
        if used < capacity:
            alloc[jid] = 1
            used += 1
            mw = caps[idx]
            if (mw is None or 2 <= mw) and 2 < len(table):
                g = _gain_double_table(Q, table, 1)
                if g > 0.0:
                    heap.append((-g, idx, 1))
        else:
            alloc[jid] = 0
    heapq.heapify(heap)
    while heap:
        neg_g, idx, w = heapq.heappop(heap)
        jid, Q, table = jobs[idx]
        if alloc[jid] != w:
            continue                      # stale: job already doubled past w
        if used + w > capacity:
            continue    # never feasible again (used only grows) -> discard
        used += w
        w2 = 2 * w
        alloc[jid] = w2
        mw = caps[idx]
        if ((mw is None or 2 * w2 <= mw) and used + w2 <= capacity
                and 2 * w2 < len(table)):
            g = _gain_double_table(Q, table, w2)
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w2))
    return alloc


def doubling_heuristic_soa(Q, tables, capacity: int,
                           max_w=None, rows=None):
    """§4.2 doubling heuristic over structure-of-arrays job state.

    The SoA twin of ``doubling_heuristic_table`` for the simulator hot
    path: ``Q`` is a float ndarray of remaining work (one entry per job,
    in allocation order), ``tables`` a 2-D ndarray whose row ``rows[i]``
    is job i's speed table (``rows=None`` means row i), and the result is
    an int64 ndarray of worker counts aligned with ``Q`` — no per-job
    tuples or dicts are materialized.  The initial w=1 gains are computed
    in one vectorized pass; the doubling loop is the same lazy max-heap
    with ``(-gain, input index, w)`` entries, so allocations (and
    tie-breaks) are bit-identical to the table/reference solvers.

    Inside the doubling loop everything is plain Python ints/floats
    (ndarray-scalar indexing would triple the per-pop cost); ``float`` /
    ``.tolist()`` conversions of float64 values are exact, so this costs
    nothing in identity.

    Only the first ``min(n, capacity)`` jobs can ever hold workers (the
    FIFO w=1 seeding exhausts the cluster), so the per-job lists are
    materialized for that prefix alone — the per-solve cost is
    O(min(n, C) + heap work) plus one O(n) zero-filled output array, not
    O(n) Python-list traffic (the wall 10k-job traces hit when thousands
    of queued jobs re-materialized per tick).
    """
    n = len(Q)
    n1 = min(n, capacity)
    out = np.zeros(n, dtype=np.int64)
    if n1 == 0:
        return out
    head = [1] * n1
    row_of = (list(range(n1)) if rows is None
              else np.asarray(rows)[:n1].tolist())
    caps = _caps_head(max_w, n, n1)
    used = n1
    W = tables.shape[1] - 1
    heap: list[tuple[float, int, int]] = []
    if 2 <= W:
        gains = _gains_w1(Q[:n1], tables, row_of)
        heap = [(-g, i, 1) for i, g in enumerate(gains)
                if g > 0.0 and (caps[i] is None or 2 <= caps[i])]
        heapq.heapify(heap)
    q_of = Q[:n1].tolist()
    while heap:
        neg_g, idx, w = heapq.heappop(heap)
        if head[idx] != w:
            continue                      # stale: job already doubled past w
        if used + w > capacity:
            continue    # never feasible again (used only grows) -> discard
        used += w
        w2 = 2 * w
        head[idx] = w2
        mw = caps[idx]
        if ((mw is None or 2 * w2 <= mw) and used + w2 <= capacity
                and 2 * w2 <= W):
            table = tables[row_of[idx]]
            gq = q_of[idx]
            g = (gq / max(float(table[w2]), 1e-12)
                 - gq / max(float(table[2 * w2]), 1e-12)) / w2
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w2))
    out[:n1] = head
    return out


def optimus_greedy_soa(Q, tables, capacity: int, max_w=None, rows=None):
    """Optimus [8] +1-greedy over structure-of-arrays job state — the SoA
    twin of ``optimus_greedy_table``, with the same prefix-only
    materialization as ``doubling_heuristic_soa`` (only the first
    ``min(n, capacity)`` jobs are ever granted workers)."""
    n = len(Q)
    n1 = min(n, capacity)
    out = np.zeros(n, dtype=np.int64)
    if n1 == 0:
        return out
    head = [1] * n1
    row_of = (list(range(n1)) if rows is None
              else np.asarray(rows)[:n1].tolist())
    caps = _caps_head(max_w, n, n1)
    used = n1
    W = tables.shape[1] - 1
    heap: list[tuple[float, int, int]] = []
    if 2 <= W:
        gains = _gains_w1(Q[:n1], tables, row_of)
        heap = [(-g, i, 1) for i, g in enumerate(gains)
                if g > 0.0 and (caps[i] is None or 2 <= caps[i])]
        heapq.heapify(heap)
    q_of = Q[:n1].tolist()
    while used < capacity and heap:
        neg_g, idx, w = heapq.heappop(heap)
        if head[idx] != w:
            continue                                   # stale entry
        w1 = w + 1
        head[idx] = w1
        used += 1
        mw = caps[idx]
        if (mw is None or w1 + 1 <= mw) and w1 + 1 <= W:
            table = tables[row_of[idx]]
            gq = q_of[idx]
            g = (gq / max(float(table[w1]), 1e-12)
                 - gq / max(float(table[w1 + 1]), 1e-12))
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w1))
    out[:n1] = head
    return out


def fixed_soa(n: int, capacity: int, w_fixed: int):
    """SoA twin of ``fixed``: first ``capacity // w_fixed`` jobs get the
    all-or-nothing gang of ``w_fixed`` (FIFO), the rest get 0."""
    out = np.zeros(n, dtype=np.int64)
    out[:min(n, capacity // w_fixed)] = w_fixed
    return out


# --------------------------------------------------------------------------
# Incremental cross-tick solver state.
#
# A fresh solve rebuilds its gain-heap from every active job at every
# reallocation event — O(J) init per tick, the wall 10k-job traces hit
# once thousands of queued jobs sit behind a 64-GPU cluster.  The
# persistent structures below carry solver state *across* ticks instead:
# a tick only touches jobs whose remaining work changed since the last
# solve (arrivals, jobs that ran) and lazily discards entries for jobs
# that completed or whose work moved on — O(Δ log J) per tick.
#
# Identity contract: every structure reproduces its fresh solver
# bit-for-bit (same float ops per entry, same (gain, arrival-order) heap
# tie-breaks), gated by the engine parity suites and the
# incremental-vs-fresh fuzz/hypothesis tests.  Entries are keyed by an
# *admission sequence number* instead of a list position: positions
# shift when earlier jobs complete, seqs never do, and both orderings
# agree because the active list preserves arrival order.
# --------------------------------------------------------------------------


class IncrementalContext:
    """Cross-tick solver state for one fast-engine run.

    The engine owns one instance per ``simulate`` call and refreshes
    ``pos_of_seq``/``start`` before every solve; policies keep their
    persistent structures (gain-heaps, remaining-time heaps) in
    ``store``.  ``pos_of_seq[s]`` is the *absolute* row of admission
    ``s`` in the engine's arrays (-1 once the job completes); the row's
    view-relative index is ``pos_of_seq[s] - start``.  The reference
    oracle never builds one, so every policy falls back to its fresh
    solver there — which is exactly what the parity gates compare
    against.
    """

    __slots__ = ("pos_of_seq", "start", "store")

    def __init__(self):
        self.pos_of_seq: np.ndarray = np.empty(0, np.int64)
        self.start = 0
        self.store: dict[str, object] = {}


class _StampedGainHeap:
    """Generation-stamped persistent base heap shared by the doubling and
    Optimus solvers.

    Holds one w=1 gain entry per candidate-prefix job (the first
    ``min(n, capacity)`` — the only jobs a FIFO-seeded solver can ever
    grant workers; jobs never leave the prefix while active because
    removals only shift rows left).  An entry ``(-gain, seq, 1, stamp)``
    stays valid while the job's remaining work is unchanged; when it
    changes (the job ran) the per-seq stamp is bumped and a fresh entry
    pushed, the old one discarded lazily at pop time.  Per-solve cost is
    O(dirty + heap copy) instead of a full O(prefix) rebuild — the win
    grows as more of the prefix sits frozen or idle between ticks.
    """

    __slots__ = ("last_q", "stamp", "base")

    def __init__(self):
        self.last_q = np.full(64, np.nan)
        self.stamp = np.zeros(64, np.int64)
        self.base: list[tuple[float, int, int, int]] = []

    def _grow_to(self, m: int) -> None:
        self.last_q = _grow_array(self.last_q, m, np.nan)
        self.stamp = _grow_array(self.stamp, m, 0)

    def _refresh(self, state: "AllocView", n1: int) -> None:
        """Bring the base heap up to date with the current prefix.

        Jobs whose remaining work changed since their entry was stamped
        (NaN-seeded, so new arrivals are dirty by construction) get a
        fresh w=1 entry; stale ones die by stamp at pop time.  When most
        of the prefix is dirty anyway (a saturated cluster doubles every
        prefix job every tick) a from-scratch rebuild is cheaper than
        accumulating one stale entry per push — the valid entry set is
        identical either way."""
        seqs = state.seq[:n1]
        self._grow_to(int(seqs[-1]) + 1)
        q = state.remaining[:n1]
        dirty = np.nonzero(self.last_q[seqs] != q)[0]
        if not len(dirty):
            return
        rebuild = 2 * len(dirty) >= n1
        if rebuild:
            dirty = np.arange(n1)
            dseq = seqs
        else:
            dseq = seqs[dirty]
        self.stamp[dseq] += 1
        self.last_q[dseq] = q[dirty]
        rows = dirty if state.rows is None else state.rows[:n1][dirty]
        # the same vectorized w=1 gain pass as the fresh solvers, over
        # the dirty slice only
        gains = _gains_w1(q[dirty], state.tables, rows)
        caps_d = state.max_w[:n1][dirty].tolist()
        stamps = self.stamp[dseq].tolist()
        if rebuild:
            self.base = [(-g, s, 1, stm)
                         for g, s, mw, stm in zip(gains, dseq.tolist(),
                                                  caps_d, stamps)
                         if g > 0.0 and 2 <= mw]
            heapq.heapify(self.base)
            return
        base = self.base
        for g, s, mw, stm in zip(gains, dseq.tolist(), caps_d, stamps):
            if g > 0.0 and 2 <= mw:
                heapq.heappush(base, (-g, s, 1, stm))

    def _maybe_compact(self, ctx: IncrementalContext, n1: int) -> None:
        if len(self.base) <= 4 * n1 + 64:
            return
        stamp, pos = self.stamp, ctx.pos_of_seq
        self.base = [e for e in self.base
                     if stamp[e[1]] == e[3] and pos[e[1]] >= 0]
        heapq.heapify(self.base)


class _PersistentDoublingHeap(_StampedGainHeap):
    """Incremental mode of ``doubling_heuristic_soa``."""

    def solve(self, state: "AllocView", capacity: int,
              ctx: IncrementalContext) -> np.ndarray:
        n = state.n
        n1 = min(n, capacity)
        out = np.zeros(n, dtype=np.int64)
        if n1 == 0:
            return out
        head = [1] * n1
        W = state.tables.shape[1] - 1
        if W < 2:
            out[:n1] = head
            return out
        self._refresh(state, n1)
        self._maybe_compact(ctx, n1)
        heap = self.base.copy()       # a copy of a heap is a heap
        used = n1
        stamp = self.stamp
        pos, start = ctx.pos_of_seq, ctx.start
        tables, rows = state.tables, state.rows
        rem, maxw = state.remaining, state.max_w
        while heap:
            neg_g, s, w, stm = heapq.heappop(heap)
            if stamp[s] != stm:
                continue              # job ran since this entry was pushed
            p = pos[s]
            if p < 0:
                continue              # job completed
            idx = int(p) - start
            if head[idx] != w:
                continue              # stale: job already doubled past w
            if used + w > capacity:
                continue    # never feasible again (used only grows)
            used += w
            w2 = 2 * w
            head[idx] = w2
            mw = int(maxw[idx])
            if 2 * w2 <= mw and used + w2 <= capacity and 2 * w2 <= W:
                table = tables[idx if rows is None else rows[idx]]
                gq = float(rem[idx])
                g = (gq / max(float(table[w2]), 1e-12)
                     - gq / max(float(table[2 * w2]), 1e-12)) / w2
                if g > 0.0:
                    heapq.heappush(heap, (-g, s, w2, stm))
        out[:n1] = head
        return out


class _PersistentOptimusHeap(_StampedGainHeap):
    """Incremental mode of ``optimus_greedy_soa`` (+1 steps)."""

    def solve(self, state: "AllocView", capacity: int,
              ctx: IncrementalContext) -> np.ndarray:
        n = state.n
        n1 = min(n, capacity)
        out = np.zeros(n, dtype=np.int64)
        if n1 == 0:
            return out
        head = [1] * n1
        W = state.tables.shape[1] - 1
        if W < 2:
            out[:n1] = head
            return out
        self._refresh(state, n1)
        self._maybe_compact(ctx, n1)
        heap = self.base.copy()
        used = n1
        stamp = self.stamp
        pos, start = ctx.pos_of_seq, ctx.start
        tables, rows = state.tables, state.rows
        rem, maxw = state.remaining, state.max_w
        while used < capacity and heap:
            neg_g, s, w, stm = heapq.heappop(heap)
            if stamp[s] != stm:
                continue
            p = pos[s]
            if p < 0:
                continue
            idx = int(p) - start
            if head[idx] != w:
                continue                               # stale entry
            w1 = w + 1
            head[idx] = w1
            used += 1
            mw = int(maxw[idx])
            if w1 + 1 <= mw and w1 + 1 <= W:
                table = tables[idx if rows is None else rows[idx]]
                gq = float(rem[idx])
                g = (gq / max(float(table[w1]), 1e-12)
                     - gq / max(float(table[w1 + 1]), 1e-12))
                if g > 0.0:
                    heapq.heappush(heap, (-g, s, w1, stm))
        out[:n1] = head
        return out


class _PersistentSRTFHeap:
    """Cross-tick remaining-time order for SRTF.

    The fresh SRTF pass argsorts every active job's best-case remaining
    time at every reallocation — O(J log J) per tick, *the* dominant cost
    of 10k-job traces (thousands of queued jobs whose remaining work
    never changes between ticks re-sorted tens of thousands of times).
    Here the order lives in a persistent min-heap of ``(t_best, seq,
    stamp)`` entries: a job's entry stays valid while it sits in the
    queue (w=0 ⇒ remaining unchanged ⇒ t_best unchanged); only last
    tick's winners (the ≤capacity jobs that actually ran) and new
    arrivals are re-stamped and re-pushed.  Per-job ``(w*, f_best)`` is
    static — cached per interned (speed-table row, cap) pair rather than
    recomputed per job per tick.
    """

    __slots__ = ("f_best", "w_star", "stamp", "heap", "winners", "seen",
                 "rowcache")

    def __init__(self):
        self.f_best = np.zeros(64)
        self.w_star = np.zeros(64, np.int64)
        self.stamp = np.zeros(64, np.int64)
        self.heap: list[tuple[float, int, int]] = []
        self.winners: list[int] = []          # seqs granted w>0 last solve
        self.seen = 0                         # seqs below this are known
        self.rowcache: dict[tuple[int, int], tuple[int, float]] = {}

    def _grow_to(self, m: int) -> None:
        self.f_best = _grow_array(self.f_best, m, 0.0)
        self.w_star = _grow_array(self.w_star, m, 0)
        self.stamp = _grow_array(self.stamp, m, 0)

    def _best(self, state: "AllocView", i: int, W: int) -> tuple[int, float]:
        """(w*, f_best) for view row ``i``: the speed-maximizing feasible
        worker count — same argmax/tie semantics as the fresh masked
        pass, cached per (interned row, cap)."""
        cap_i = min(int(state.max_w[i]), W)
        row = i if state.rows is None else int(state.rows[i])
        key = (row, cap_i)
        got = self.rowcache.get(key)
        if got is None:
            tab = state.tables[row]
            w_star = int(np.argmax(tab[1:cap_i + 1])) + 1
            got = (w_star, float(tab[w_star]))
            self.rowcache[key] = got
        return got

    def solve(self, state: "AllocView", capacity: int,
              ctx: IncrementalContext) -> np.ndarray:
        n = state.n
        out = np.zeros(n, dtype=np.int64)
        if n == 0:
            self.winners = []
            return out
        W = state.tables.shape[1] - 1
        if W < 1:
            self.winners = []
            return out
        seq = state.seq
        rem = state.remaining
        pos, start = ctx.pos_of_seq, ctx.start
        heap = self.heap
        # register new arrivals (a strictly-increasing suffix of `seq`)
        first_new = int(np.searchsorted(seq, self.seen))
        if first_new < n:
            self._grow_to(int(seq[-1]) + 1)
            for i in range(first_new, n):
                s = int(seq[i])
                w_star, f = self._best(state, i, W)
                self.w_star[s] = w_star
                self.f_best[s] = f
                self.stamp[s] += 1
                heapq.heappush(heap, (float(rem[i]) / max(f, 1e-12), s,
                                      int(self.stamp[s])))
            self.seen = int(seq[-1]) + 1
        # re-stamp last tick's winners: the only jobs whose remaining
        # work (hence t_best) can have moved
        for s in self.winners:
            p = pos[s]
            if p < 0:
                continue                       # completed since
            i = int(p) - start
            self.stamp[s] += 1
            heapq.heappush(heap, (float(rem[i])
                                  / max(float(self.f_best[s]), 1e-12), s,
                                  int(self.stamp[s])))
        stamp = self.stamp
        cap = capacity
        winners: list[int] = []
        tables, rows, maxw = state.tables, state.rows, state.max_w
        while cap > 0 and heap:
            tb, s, stm = heapq.heappop(heap)
            if stamp[s] != stm:
                continue
            p = pos[s]
            if p < 0:
                continue
            i = int(p) - start
            cap_i = min(int(maxw[i]), W)
            hi = cap_i if cap_i < cap else cap
            w = int(self.w_star[s])
            if w > hi:      # clipped by remaining capacity: re-derive
                row = i if rows is None else int(rows[i])
                w = int(np.argmax(tables[row, 1:hi + 1])) + 1
            out[i] = w
            cap -= w
            winners.append(s)
        self.winners = winners
        if len(heap) > 2 * n + 1024:
            self.heap = [e for e in heap
                         if stamp[e[1]] == e[2] and pos[e[1]] >= 0]
            heapq.heapify(self.heap)
        return out


def optimus_greedy_table(jobs: Sequence[TableJobTuple], capacity: int,
                         max_w: int | None = None) -> Alloc:
    """Optimus [8] over precomputed speed tables, with a lazy max-heap."""
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    heap: list[tuple[float, int, int]] = []   # (-gain, input index, w)

    def entry(idx: int, Q: float, table, w: int):
        """Heap entry for the +1 gain at w, or None if never selectable."""
        if max_w is not None and w + 1 > max_w:
            return None
        if w + 1 >= len(table):
            return None    # beyond the table bound => capacity-infeasible
        g = Q / max(table[w], 1e-12) - Q / max(table[w + 1], 1e-12)
        return (-g, idx, w) if g > 0.0 else None

    for idx, (jid, Q, table) in enumerate(jobs):
        if used < capacity:
            alloc[jid] = 1
            used += 1
            e = entry(idx, Q, table, 1)
            if e is not None:
                heap.append(e)
        else:
            alloc[jid] = 0
    heapq.heapify(heap)
    while used < capacity and heap:
        neg_g, idx, w = heapq.heappop(heap)
        jid, Q, table = jobs[idx]
        if alloc[jid] != w:
            continue                                   # stale entry
        alloc[jid] = w + 1
        used += 1
        e = entry(idx, Q, table, w + 1)
        if e is not None:
            heapq.heappush(heap, e)
    return alloc


def exact_dp_table(jobs: Sequence[TableJobTuple], capacity: int,
                   max_w: int | None = None,
                   powers_of_two: bool = False) -> Alloc:
    """Exact minimizer of Σ Q_j / f_j(w_j) by DP over capacity, from tables.

    Same DP (and identical tie-breaking) as the callable version; per-job
    costs Q/f(w) are precomputed once per job instead of re-evaluating the
    speed model in the O(J * C * W) inner loop.
    """
    jobs = list(jobs)
    J = len(jobs)
    wmax = min(max_w or capacity, capacity)
    choices = ([2 ** k for k in range(int(math.log2(wmax)) + 1)]
               if powers_of_two else list(range(1, wmax + 1)))
    assert J <= capacity, "exact_dp assumes every job can get >=1 worker (Z+)"
    dp = {0: (0.0, ())}
    for (jid, Q, table) in jobs:
        costs = [Q / max(table[w], 1e-12) for w in choices]
        ndp: dict[int, tuple[float, tuple]] = {}
        for c, (cost, chosen) in dp.items():
            for w, t in zip(choices, costs):
                nc = c + w
                if nc > capacity:
                    continue
                cand = (cost + t, chosen + (w,))
                if nc not in ndp or cand[0] < ndp[nc][0]:
                    ndp[nc] = cand
        dp = ndp
    best_cost, best_alloc = min(dp.values(), key=lambda kv: kv[0])
    return {jid: w for (jid, _, _), w in zip(jobs, best_alloc)}


# --------------------------------------------------------------------------
# Callable-based API: thin adapters over the table solvers.
# --------------------------------------------------------------------------

def doubling_heuristic(jobs: Sequence[JobTuple], capacity: int,
                       max_w=None) -> Alloc:
    bound = _table_bound(capacity, max_w)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return doubling_heuristic_table(tjobs, capacity, max_w)


def optimus_greedy(jobs: Sequence[JobTuple], capacity: int,
                   max_w: int | None = None) -> Alloc:
    bound = _table_bound(capacity, max_w)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return optimus_greedy_table(tjobs, capacity, max_w)


def exact_dp(jobs: Sequence[JobTuple], capacity: int,
             max_w: int | None = None, powers_of_two: bool = False) -> Alloc:
    # the DP normalizes with `max_w or capacity` (0 means unbounded, seed
    # semantics), so the sampled table must use the same bound
    bound = min(max_w or capacity, capacity)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return exact_dp_table(tjobs, capacity, max_w, powers_of_two)


def fixed(jobs: Sequence[JobTuple], capacity: int, w_fixed: int) -> Alloc:
    """Every job requests w_fixed GPUs, granted FIFO while capacity lasts."""
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        w = min(w_fixed, capacity - used)
        w = w if w == w_fixed else 0    # all-or-nothing gang allocation
        alloc[jid] = w
        used += w
    return alloc


def total_time(jobs: Sequence[JobTuple], alloc: Alloc) -> float:
    tot = 0.0
    for (jid, Q, f) in jobs:
        w = alloc.get(jid, 0)
        if w > 0:
            tot += Q / max(f(w), 1e-12)
    return tot


# --------------------------------------------------------------------------
# Scheduling-policy registry.
#
# A policy is the cluster-level strategy Table 3 sweeps: given the active
# set (as SoA views — the representation both simulator engines share) and
# the ClusterModel, produce a worker-count target per job.  Policies are
# constructed exclusively through ``get_policy("spec")`` so every consumer
# (simulator engines, run_table3, benchmarks, tests) resolves strategy
# strings in exactly one place, with validation instead of str.split
# crashes deep in the event loop.
# --------------------------------------------------------------------------

# §7 simulation constants the exploratory policy and both engines share.
EXPLORE_SEGMENT = 150.0      # 2.5 minutes at each of 1, 2, 4, 8 (§7)
EXPLORE_WS = (1, 2, 4, 8)
RESCHEDULE_EVERY = 150.0     # == EXPLORE_SEGMENT (segment switches land
                             # exactly on reschedule ticks — load-bearing)


@dataclasses.dataclass
class AllocView:
    """Structure-of-arrays view of the active set, in reference-list order
    (arrival order with in-place removals — the order is load-bearing for
    solver tie-breaks, FIFO fixed grants and explore-gang grants).

    ``tables`` may be wider than the active set (the simulator's
    preallocated matrix); row ``rows[i]`` — or row i when ``rows`` is
    None — is job i's speed table.
    """
    remaining: np.ndarray                # (n,) remaining work (epochs)
    tables: np.ndarray                   # 2-D speed-table matrix
    max_w: np.ndarray                    # (n,) per-job scale-out caps
    explore_started: np.ndarray          # (n,) explore-phase start, -inf
                                         # when the job never profiles
    rows: np.ndarray | None = None       # job i's row in `tables`
    # node-level snapshot (repro.core.placement.PlacementView) when the
    # cluster runs a placement engine; None on flat/legacy clusters
    placement: object | None = None
    # cross-tick solver state (fast engine only): per-job admission
    # sequence numbers (strictly increasing in view order) and the
    # engine-owned IncrementalContext.  None from the reference oracle
    # and ad-hoc callers, which makes every policy take its fresh-solve
    # path — the identity baseline the parity gates compare against.
    seq: np.ndarray | None = None
    inc: IncrementalContext | None = None

    @property
    def n(self) -> int:
        return len(self.remaining)

    def row_of(self, i: int) -> np.ndarray:
        return self.tables[i if self.rows is None else self.rows[i]]


class SchedulingPolicy:
    """One cluster scheduling strategy.

    Subclasses set ``spec`` (the canonical string, e.g. ``"fixed_8"``) and
    implement :meth:`allocate`.  ``static`` declares that the target
    depends only on the active set's identity/order (not on remaining
    work), which lets the fast engine reuse a solve across pure reschedule
    ticks; ``explores`` makes the simulator stamp newly admitted jobs with
    an explore-phase start time.
    """

    spec: str = "?"
    static: bool = False
    explores: bool = False

    def allocate(self, state: AllocView, cluster: ClusterModel,
                 now: float) -> np.ndarray:
        """Return int64 worker counts aligned with ``state`` order."""
        raise NotImplementedError

    def validate(self, cluster: ClusterModel) -> None:
        """Reject cluster/policy combinations that can never make progress
        (called once by ``simulate`` before the event loop starts)."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


@dataclasses.dataclass(frozen=True)
class _PolicyEntry:
    factory: Callable[[str | None], SchedulingPolicy]
    example: str            # a runnable spec, e.g. "fixed_8" for "fixed"


_POLICY_REGISTRY: dict[str, _PolicyEntry] = {}


def register_policy(name: str,
                    factory: Callable[[str | None], SchedulingPolicy],
                    example: str | None = None) -> None:
    """Register a policy under ``name``.

    ``factory(param)`` receives the parameter suffix of the spec string
    (``"8"`` for ``"fixed_8"``, None for a bare name) and must validate
    it.  ``example`` is a runnable spec for registry-wide parity gates
    (defaults to ``name`` — required for parameterized policies whose
    bare name is not runnable).
    """
    if name in _POLICY_REGISTRY:
        raise ValueError(f"policy {name!r} already registered")
    _POLICY_REGISTRY[name] = _PolicyEntry(factory, example or name)


def registered_policies() -> dict[str, str]:
    """``{name: runnable example spec}`` for every registered policy —
    the iteration surface for the CI parity gate and the docs."""
    return {n: e.example for n, e in sorted(_POLICY_REGISTRY.items())}


def get_policy(spec: str | SchedulingPolicy) -> SchedulingPolicy:
    """Resolve a strategy spec string into a policy instance.

    Exact registry names win (``"utility_greedy"``); otherwise the part
    after the last underscore is the policy parameter (``"fixed_8"`` ->
    ``fixed`` with k=8).  Malformed specs fail here, loudly, instead of
    dying inside ``str.split``/``int()`` deep in the engine.
    """
    if isinstance(spec, SchedulingPolicy):
        return spec
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"policy spec must be a non-empty string, "
                         f"got {spec!r}")
    base, param = _split_spec(_POLICY_REGISTRY, spec)
    entry = _POLICY_REGISTRY.get(base)
    if entry is None:
        raise ValueError(
            f"unknown scheduling policy {spec!r}; registered: "
            f"{', '.join(sorted(_POLICY_REGISTRY))}")
    return entry.factory(param)


def _split_spec(registry, spec: str) -> tuple[str, str | None]:
    """Longest registered prefix at an underscore boundary wins, so a
    name parameterized by another spec ("pack_utility_greedy" -> pack
    with param "utility_greedy") parses as well as "fixed_8".  Shared by
    the policy and admission-rule registries."""
    base, param = spec, None
    while base not in registry and "_" in base:
        base, tail = base.rsplit("_", 1)
        param = tail if param is None else f"{tail}_{param}"
    return base, param


def _no_param(name: str, param: str | None, noun: str = "policy") -> None:
    if param is not None:
        raise ValueError(f"{noun} {name!r} takes no parameter, "
                         f"got {name}_{param}")


def _int_param(name: str, param: str | None, example: str,
               noun: str = "policy") -> int:
    if param is None:
        raise ValueError(f"{noun} {name!r} needs an integer parameter, "
                         f"e.g. {example!r}")
    try:
        value = int(param)
    except ValueError:
        raise ValueError(f"{noun} parameter must be an integer, got "
                         f"{name}_{param}") from None
    if value < 1:
        raise ValueError(f"{noun} parameter must be >= 1, got "
                         f"{name}_{param}")
    return value


def _persistent(state: AllocView, key: str, cls):
    """The policy's persistent solver state for this engine run, or None
    when no incremental context is available (reference oracle, ad-hoc
    views) and the fresh solver must run instead."""
    if state.inc is None or state.seq is None:
        return None
    store = state.inc.store
    inst = store.get(key)
    if inst is None:
        inst = store[key] = cls()
    return inst


class DoublingPolicy(SchedulingPolicy):
    """``precompute`` (§7): resource models known up front, the §4.2
    doubling heuristic over the whole active set at every reallocation.
    Under the fast engine the solve is incremental — a persistent
    generation-stamped gain-heap carried across ticks."""

    spec = "precompute"

    def allocate(self, state, cluster, now):
        inc = _persistent(state, "doubling", _PersistentDoublingHeap)
        if inc is not None:
            return inc.solve(state, cluster.capacity, state.inc)
        return doubling_heuristic_soa(state.remaining, state.tables,
                                      cluster.capacity, max_w=state.max_w,
                                      rows=state.rows)


class ExploratoryPolicy(SchedulingPolicy):
    """``exploratory`` (§7): a new job spends 2.5 min at each of
    w = 1, 2, 4, 8 to collect the (w, f(w)) points eq. 5 needs, inside a
    gang reservation of min(8, remaining capacity); everyone else shares
    what is left through the doubling heuristic."""

    spec = "exploratory"
    explores = True

    def allocate(self, state, cluster, now):
        n = state.n
        cap = cluster.capacity
        target = np.zeros(n, np.int64)
        # -inf marks never-profiling jobs; keep them out of the floor
        # divide (inf // x is nan + a RuntimeWarning)
        profiling = np.isfinite(state.explore_started)
        seg = np.full(n, np.inf)
        if profiling.any():
            seg[profiling] = ((now - state.explore_started[profiling])
                              // EXPLORE_SEGMENT)
        explorer = seg < len(EXPLORE_WS)
        for i in np.nonzero(explorer)[0]:
            grant = min(8, cap)
            target[i] = min(EXPLORE_WS[int(seg[i])], grant)
            cap -= grant
        assert cap >= 0, "explore gang grants exceeded cluster capacity"
        dyn = np.nonzero(~explorer)[0]
        rows = dyn if state.rows is None else state.rows[dyn]
        target[dyn] = doubling_heuristic_soa(
            state.remaining[dyn], state.tables, cap,
            max_w=state.max_w[dyn], rows=rows)
        return target


class FixedPolicy(SchedulingPolicy):
    """``fixed_k`` (§7 baselines): every job requests a constant gang of
    k workers, granted all-or-nothing FIFO while capacity lasts."""

    static = True

    def __init__(self, k: int):
        self.k = k
        self.spec = f"fixed_{k}"

    def allocate(self, state, cluster, now):
        return fixed_soa(state.n, cluster.capacity, self.k)

    def validate(self, cluster):
        if self.k > cluster.capacity:
            raise ValueError(
                f"{self.spec!r} can never run a job on a "
                f"{cluster.capacity}-GPU cluster (gang size must be in "
                f"[1, capacity])")


class SRTFPolicy(SchedulingPolicy):
    """Shortest-remaining-time-first: jobs ranked by their best-case
    remaining service time (Q / max_w f(w)); each, in that order, gets its
    speed-maximizing feasible worker count until capacity runs out.

    The classic size-based discipline the doubling heuristic implicitly
    approximates under contention — here as an explicit policy so the two
    can be compared head-to-head on heavy-tailed workloads.
    """

    spec = "srtf"

    def allocate(self, state, cluster, now):
        inc = _persistent(state, "srtf", _PersistentSRTFHeap)
        if inc is not None:
            return inc.solve(state, cluster.capacity, state.inc)
        n = state.n
        cap = cluster.capacity
        target = np.zeros(n, np.int64)
        W = state.tables.shape[1] - 1
        # ranking pass, vectorized (this policy is non-static, so allocate
        # re-runs at every event — a per-job Python loop here would be the
        # slowest path in the engine on 1000-job traces).  Slicing to the
        # fleet-wide cap (max_w is 8..16 vs a 64-wide table) and avoiding
        # the fancy-index row copy cut the 1000-job trace from ~1.0 s to
        # ~0.5 s; the speed-argmax is precomputed per job and only
        # re-derived in the loop when the remaining capacity clips it
        # (clipping drops trailing columns only, so ties still resolve to
        # the same, earliest, w).
        tabs = (state.tables[:n] if state.rows is None
                else state.tables[state.rows])
        caps = np.minimum(state.max_w, W)
        wcap = min(int(caps.max()), W) if n else 0
        if wcap < 1:
            return target
        masked = np.where(np.arange(1, wcap + 1)[None, :] <= caps[:, None],
                          tabs[:, 1:wcap + 1], 0.0)
        w_star = np.argmax(masked, axis=1) + 1
        f_best = masked[np.arange(n), w_star - 1]
        t_best = state.remaining / np.maximum(f_best, 1e-12)
        w_star = w_star.tolist()
        # stable sort: FIFO order breaks remaining-time ties
        for i in np.argsort(t_best, kind="stable").tolist():
            if cap <= 0:
                break
            hi = min(int(caps[i]), cap)
            if hi < 1:
                continue
            w = w_star[i]
            if w > hi:      # clipped by remaining capacity: re-derive
                w = int(np.argmax(tabs[i, 1:hi + 1])) + 1
            target[i] = w
            cap -= w
        return target


class UtilityGreedyPolicy(SchedulingPolicy):
    """GADGET-style utility greedy (arXiv 2202.01158): grow the job whose
    next ring-doubling adds the most cluster *throughput* per GPU.

    Start everyone at w=1 (FIFO), then repeatedly double the job with the
    best marginal utility (f(2w) - f(w)) / w.  Unlike the paper's
    ``precompute`` gain (eq. 6), the utility is Q-independent — the policy
    maximizes aggregate epochs/sec rather than total completion time, so
    it is blind to job sizes (and ``static``: a pure reschedule tick with
    an unchanged active set reuses the previous solve).
    """

    spec = "utility_greedy"
    static = True

    def allocate(self, state, cluster, now):
        n = state.n
        capacity = cluster.capacity
        n1 = min(n, capacity)
        out = np.zeros(n, dtype=np.int64)
        if n1 == 0:
            return out
        # only the FIFO w=1 prefix can ever be granted workers: keep the
        # per-job Python materialization to that prefix (10k-job traces
        # queue thousands of jobs behind it)
        caps = state.max_w[:n1].tolist()
        head = [1] * n1
        used = n1
        W = state.tables.shape[1] - 1
        heap: list[tuple[float, int, int]] = []
        for i in range(n1):
            if 2 <= min(caps[i], W):
                table = state.row_of(i)
                g = float(table[2]) - float(table[1])
                if g > 0.0:
                    heap.append((-g, i, 1))
        heapq.heapify(heap)
        while heap:
            neg_g, idx, w = heapq.heappop(heap)
            if head[idx] != w:
                continue                  # stale: job already doubled past w
            if used + w > capacity:
                continue                  # never feasible again -> discard
            used += w
            w2 = 2 * w
            head[idx] = w2
            if 2 * w2 <= min(caps[idx], W) and used + w2 <= capacity:
                table = state.row_of(idx)
                g = (float(table[2 * w2]) - float(table[w2])) / w2
                if g > 0.0:
                    heapq.heappush(heap, (-g, idx, w2))
        out[:n1] = head
        return out


class OptimusPolicy(SchedulingPolicy):
    """``optimus``: the Optimus [8] +1-greedy baseline as a cluster
    policy — grow the job whose next *single* worker buys the most
    completion-time reduction.  The §4.2 motivation's head-to-head rival
    (+1 greedy stalls at the power-of-two cliff where doubling steps
    over it); under the fast engine it shares the persistent
    gain-heap machinery with ``precompute``."""

    spec = "optimus"

    def allocate(self, state, cluster, now):
        inc = _persistent(state, "optimus", _PersistentOptimusHeap)
        if inc is not None:
            return inc.solve(state, cluster.capacity, state.inc)
        return optimus_greedy_soa(state.remaining, state.tables,
                                  cluster.capacity, max_w=state.max_w,
                                  rows=state.rows)


class PackPolicy(SchedulingPolicy):
    """Placement-aware wrapper (``pack_<policy>``): clamp every job's
    scale-out cap to the largest node, so gangs never span the slow
    inter-node fabric — the ≤20-line recipe for making any registered
    policy topology-aware (the inner policy sees flat speed tables under
    a placement engine and would otherwise overestimate spanning rings).
    """

    def __init__(self, inner: SchedulingPolicy):
        self.inner = inner
        self.spec = f"pack_{inner.spec}"
        self.static = inner.static
        self.explores = inner.explores

    def allocate(self, state, cluster, now):
        node_cap = max(n.gpus for n in cluster.node_specs())
        clamped = dataclasses.replace(
            state, max_w=np.minimum(state.max_w, node_cap))
        return self.inner.allocate(clamped, cluster, now)

    def validate(self, cluster):
        self.inner.validate(cluster)


def _parameterless(name: str, cls: type[SchedulingPolicy]):
    def factory(param: str | None) -> SchedulingPolicy:
        _no_param(name, param)
        return cls()
    return factory


register_policy("precompute", _parameterless("precompute", DoublingPolicy))
register_policy("exploratory",
                _parameterless("exploratory", ExploratoryPolicy))
register_policy("fixed",
                lambda p: FixedPolicy(_int_param("fixed", p, "fixed_8")),
                example="fixed_8")
register_policy("srtf", _parameterless("srtf", SRTFPolicy))
register_policy("optimus", _parameterless("optimus", OptimusPolicy))
register_policy("utility_greedy",
                _parameterless("utility_greedy", UtilityGreedyPolicy))


def _pack_factory(param: str | None) -> SchedulingPolicy:
    if param is None:
        raise ValueError("policy 'pack' wraps another policy spec, "
                         "e.g. 'pack_srtf' or 'pack_precompute'")
    return PackPolicy(get_policy(param))


register_policy("pack", _pack_factory, example="pack_srtf")
