"""Resource allocation — paper §4 — and the scheduling-policy registry.

The problem (§4.1):   min Σ_j t_j,  t_j = Q_j / f_j(w_j),
                      Σ_j w_j <= C,  w_j in Z+           (NP-hard, non-convex)

Solvers:
  * ``doubling_heuristic``  — §4.2, the paper's contribution: start every job
    at 1 worker, repeatedly *double* the job with the best average marginal
    gain (Q/f(w) - Q/f(2w)) / w.  Doubling steps over the power-of-two
    cliff (8 -> 9 is a per-GPU regression under doubling-halving; 8 -> 16 is
    not), where +1 greedy stalls.
  * ``optimus_greedy``      — the Optimus baseline: +1 worker at a time.
  * ``exact_dp``            — exact DP over worker counts (validation).
  * ``fixed``               — every job requests a constant w (§7 baselines).

Solver API layers, one semantics:

  * *SoA* (``doubling_heuristic_soa`` / ``optimus_greedy_soa`` /
    ``fixed_soa``) take the simulator's structure-of-arrays state directly
    — a remaining-work ndarray plus a 2-D speed-table ndarray — and return
    an int64 allocation array aligned with the input, so the event loop
    never materializes per-job tuples.  Initial w=1 gains are one
    vectorized pass over the ``min(n, capacity)`` candidate prefix (the
    only jobs a FIFO-seeded solver can ever grant workers); the
    doubling/+1 loop is the same lazy max-heap as the table layer.
  * *Incremental* (``_PersistentDoublingHeap`` / ``_PersistentOptimusHeap``
    / ``_PersistentSRTFHeap``, engaged automatically when the fast engine's
    :class:`IncrementalContext` rides on the view) carry the gain-heap /
    remaining-time order *across* reallocation ticks, keyed by a
    generation-stamped admission sequence: a tick pushes entries only for
    jobs whose remaining work moved (arrivals, jobs that ran) and lazily
    discards entries for completed or re-stamped jobs — O(Δ log J) per
    tick instead of an O(J) rebuild, allocation-for-allocation identical
    to the fresh solvers (fuzz-, property- and trace-gated).
  * *Table-driven* (``doubling_heuristic_table`` & friends) take jobs as
    (job_id, Q, speed_table) where ``speed_table[w]`` is f(w) for
    w = 0..max index.  Gains come from O(1) array lookups, and the
    doubling/greedy loops pop a lazy max-heap instead of rescanning all J
    jobs per step.  A job's marginal gain depends only on its own (Q, w),
    so heap entries never need recomputation: an entry is pushed when the
    job reaches w and is simply discarded as stale if the job's allocation
    has moved on by the time it is popped.
  * *Callable-based* (``doubling_heuristic`` & friends) keep the original
    (job_id, Q, speed_fn) signature as thin adapters: they sample the
    callable once into a table and delegate.  Allocation-for-allocation
    identical to the pre-table implementations (the ``*_ref`` seed
    versions now live in ``repro.core._reference``, used only by parity
    tests and ``benchmarks/bench_scheduler.py``).

Tie-breaking matches the original scan exactly: among equal best gains the
job earliest in the input sequence wins, which the heap encodes by ordering
entries (-gain, input_index).

On top of the solvers sits the **policy registry** (bottom of this
module): every cluster strategy — the paper's ``precompute`` /
``exploratory`` / ``fixed_k`` plus SRTF, the Optimus +1-greedy and the
GADGET-style utility greedy — is a :class:`SchedulingPolicy` with one
``allocate(state, cluster, now)`` entry point over the SoA views
(:class:`AllocView`).  Both simulator engines, the benchmarks and the
tests construct policies exclusively through :func:`get_policy`, so a new
strategy is one registered class — not three parallel solver stacks.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Sequence

import numpy as np

from repro.collectives.cost import ClusterModel

Alloc = dict[int, int]
JobTuple = tuple[int, float, Callable[[int], float]]  # (id, Q, speed_fn)
# (id, Q, speed_table) with speed_table[w] = f(w), index 0 unused (= 0.0);
# any indexable works, but a plain list avoids ndarray-scalar overhead
TableJobTuple = tuple[int, float, Sequence[float]]


def _gain_double(Q: float, f, w: int) -> float:
    """Average marginal gain of doubling w -> 2w, per added GPU (eq. 6)."""
    t_now = Q / max(f(w), 1e-12)
    t_next = Q / max(f(2 * w), 1e-12)
    return (t_now - t_next) / w


def _gain_double_table(Q: float, table, w: int) -> float:
    """Eq. 6 gain from a speed table — same float ops as ``_gain_double``."""
    t_now = Q / max(table[w], 1e-12)
    t_next = Q / max(table[2 * w], 1e-12)
    return (t_now - t_next) / w


def _table_bound(capacity: int, max_w) -> int:
    """Largest w any solver ever evaluates: min(max_w, capacity).

    Doubling only scores w -> 2w when the extra w workers still fit
    (used + w <= capacity with used >= w, so 2w <= capacity) and
    2w <= max_w; +1 greedy only scores w+1 <= capacity and <= max_w.
    With per-job caps the bound is the largest cap in the fleet.
    """
    if max_w is None:
        return capacity
    if hasattr(max_w, "__len__"):
        return min(max(max_w) if len(max_w) else capacity, capacity)
    return min(max_w, capacity)


def _caps(max_w, n: int) -> list:
    """Normalize ``max_w`` to one cap per job.

    The doubling solvers accept ``max_w`` as None (unbounded), a scalar
    (every job shares the cap — the paper's single-node-fleet setup), or a
    sequence/ndarray of per-job caps aligned with the job order
    (heterogeneous fleets, e.g. the ``mixed_maxw`` workload pattern).
    """
    if hasattr(max_w, "__len__"):
        caps = list(max_w)
        assert len(caps) == n, f"per-job max_w length {len(caps)} != {n}"
        return caps
    return [max_w] * n


def _caps_head(max_w, n: int, n1: int) -> list:
    """``_caps`` for the first ``n1`` jobs only — the SoA solvers never
    grant workers past the ``min(n, capacity)`` prefix, so the rest of a
    per-job cap array is never read."""
    if hasattr(max_w, "__len__"):
        assert len(max_w) == n, f"per-job max_w length {len(max_w)} != {n}"
        head = max_w[:n1]
        return head.tolist() if isinstance(head, np.ndarray) else list(head)
    return [max_w] * n1


def _gains_w1(Q, tables, rows) -> list[float]:
    """Vectorized w=1 gain pass shared by the fresh SoA solvers and the
    persistent heaps' refresh: per added GPU, (Q/f(1) - Q/f(2)) / 1 —
    identical for the doubling and +1 step rules at w=1, and elementwise
    (the same float values regardless of which jobs share the vector)."""
    t_now = Q / np.maximum(tables[rows, 1], 1e-12)
    t_next = Q / np.maximum(tables[rows, 2], 1e-12)
    return (t_now - t_next).tolist()


def _grow_array(arr: np.ndarray, m: int, fill) -> np.ndarray:
    """``arr`` doubled (repeatedly) to hold at least ``m`` entries, new
    slots set to ``fill`` — the one growth pattern every per-seq array in
    this module shares."""
    cap = len(arr)
    if m <= cap:
        return arr
    while cap < m:
        cap *= 2
    new = np.full(cap, fill, arr.dtype)
    new[:len(arr)] = arr
    return new


def _out_buf(out, n: int) -> np.ndarray:
    """Zeroed int64 target of length ``n``: a fresh array, or the head
    of a caller-reused scratch buffer (the fresh SoA solvers' remaining
    per-solve O(n) allocation, opt-out for hot callers)."""
    if out is None:
        return np.zeros(n, dtype=np.int64)
    out = out[:n]
    out[:] = 0
    return out


def _sample_table(f: Callable[[int], float], max_index: int) -> list[float]:
    return [0.0] + [f(w) for w in range(1, max_index + 1)]


def doubling_heuristic_table(jobs: Sequence[TableJobTuple], capacity: int,
                             max_w=None) -> Alloc:
    """§4.2 doubling heuristic over precomputed speed tables.

    Lazy max-heap over doubling gains: O((J + doublings) log J) instead of
    the reference implementation's O(J) rescan per doubling step.
    ``max_w`` may be a scalar or per-job caps (see ``_caps``).
    """
    jobs = list(jobs)
    caps = _caps(max_w, len(jobs))
    alloc: Alloc = {}
    used = 0
    heap: list[tuple[float, int, int]] = []   # (-gain, input index, w)
    for idx, (jid, Q, table) in enumerate(jobs):
        if used < capacity:
            alloc[jid] = 1
            used += 1
            mw = caps[idx]
            if (mw is None or 2 <= mw) and 2 < len(table):
                g = _gain_double_table(Q, table, 1)
                if g > 0.0:
                    heap.append((-g, idx, 1))
        else:
            alloc[jid] = 0
    heapq.heapify(heap)
    while heap:
        neg_g, idx, w = heapq.heappop(heap)
        jid, Q, table = jobs[idx]
        if alloc[jid] != w:
            continue                      # stale: job already doubled past w
        if used + w > capacity:
            continue    # never feasible again (used only grows) -> discard
        used += w
        w2 = 2 * w
        alloc[jid] = w2
        mw = caps[idx]
        if ((mw is None or 2 * w2 <= mw) and used + w2 <= capacity
                and 2 * w2 < len(table)):
            g = _gain_double_table(Q, table, w2)
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w2))
    return alloc


def doubling_heuristic_soa(Q, tables, capacity: int,
                           max_w=None, rows=None, out=None):
    """§4.2 doubling heuristic over structure-of-arrays job state.

    The SoA twin of ``doubling_heuristic_table`` for the simulator hot
    path: ``Q`` is a float ndarray of remaining work (one entry per job,
    in allocation order), ``tables`` a 2-D ndarray whose row ``rows[i]``
    is job i's speed table (``rows=None`` means row i), and the result is
    an int64 ndarray of worker counts aligned with ``Q`` — no per-job
    tuples or dicts are materialized.  The initial w=1 gains are computed
    in one vectorized pass; the doubling loop is the same lazy max-heap
    with ``(-gain, input index, w)`` entries, so allocations (and
    tie-breaks) are bit-identical to the table/reference solvers.

    Inside the doubling loop everything is plain Python ints/floats
    (ndarray-scalar indexing would triple the per-pop cost); ``float`` /
    ``.tolist()`` conversions of float64 values are exact, so this costs
    nothing in identity.

    Only the first ``min(n, capacity)`` jobs can ever hold workers (the
    FIFO w=1 seeding exhausts the cluster), so the per-job lists are
    materialized for that prefix alone — the per-solve cost is
    O(min(n, C) + heap work) plus one O(n) zero-filled output array
    (pass ``out``, a reusable int64 buffer of length >= n, to avoid
    even that; the engine's hot path avoids dense targets entirely via
    the :class:`AllocDelta` contract).
    """
    n = len(Q)
    n1 = min(n, capacity)
    out = _out_buf(out, n)
    if n1 == 0:
        return out
    head = [1] * n1
    row_of = (list(range(n1)) if rows is None
              else np.asarray(rows)[:n1].tolist())
    caps = _caps_head(max_w, n, n1)
    used = n1
    W = tables.shape[1] - 1
    heap: list[tuple[float, int, int]] = []
    if 2 <= W:
        gains = _gains_w1(Q[:n1], tables, row_of)
        heap = [(-g, i, 1) for i, g in enumerate(gains)
                if g > 0.0 and (caps[i] is None or 2 <= caps[i])]
        heapq.heapify(heap)
    q_of = Q[:n1].tolist()
    while heap:
        neg_g, idx, w = heapq.heappop(heap)
        if head[idx] != w:
            continue                      # stale: job already doubled past w
        if used + w > capacity:
            continue    # never feasible again (used only grows) -> discard
        used += w
        w2 = 2 * w
        head[idx] = w2
        mw = caps[idx]
        if ((mw is None or 2 * w2 <= mw) and used + w2 <= capacity
                and 2 * w2 <= W):
            table = tables[row_of[idx]]
            gq = q_of[idx]
            g = (gq / max(float(table[w2]), 1e-12)
                 - gq / max(float(table[2 * w2]), 1e-12)) / w2
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w2))
    out[:n1] = head
    return out


def optimus_greedy_soa(Q, tables, capacity: int, max_w=None, rows=None,
                       out=None):
    """Optimus [8] +1-greedy over structure-of-arrays job state — the SoA
    twin of ``optimus_greedy_table``, with the same prefix-only
    materialization (and reusable ``out`` buffer) as
    ``doubling_heuristic_soa``."""
    n = len(Q)
    n1 = min(n, capacity)
    out = _out_buf(out, n)
    if n1 == 0:
        return out
    head = [1] * n1
    row_of = (list(range(n1)) if rows is None
              else np.asarray(rows)[:n1].tolist())
    caps = _caps_head(max_w, n, n1)
    used = n1
    W = tables.shape[1] - 1
    heap: list[tuple[float, int, int]] = []
    if 2 <= W:
        gains = _gains_w1(Q[:n1], tables, row_of)
        heap = [(-g, i, 1) for i, g in enumerate(gains)
                if g > 0.0 and (caps[i] is None or 2 <= caps[i])]
        heapq.heapify(heap)
    q_of = Q[:n1].tolist()
    while used < capacity and heap:
        neg_g, idx, w = heapq.heappop(heap)
        if head[idx] != w:
            continue                                   # stale entry
        w1 = w + 1
        head[idx] = w1
        used += 1
        mw = caps[idx]
        if (mw is None or w1 + 1 <= mw) and w1 + 1 <= W:
            table = tables[row_of[idx]]
            gq = q_of[idx]
            g = (gq / max(float(table[w1]), 1e-12)
                 - gq / max(float(table[w1 + 1]), 1e-12))
            if g > 0.0:
                heapq.heappush(heap, (-g, idx, w1))
    out[:n1] = head
    return out


def fixed_soa(n: int, capacity: int, w_fixed: int, out=None):
    """SoA twin of ``fixed``: first ``capacity // w_fixed`` jobs get the
    all-or-nothing gang of ``w_fixed`` (FIFO), the rest get 0."""
    out = _out_buf(out, n)
    out[:min(n, capacity // w_fixed)] = w_fixed
    return out


# --------------------------------------------------------------------------
# Incremental cross-tick solver state + the sparse allocation-delta
# contract.
#
# A fresh solve rebuilds its gain-heap from every active job at every
# reallocation event — O(J) init per tick, the wall 10k-job traces hit
# once thousands of queued jobs sit behind a 64-GPU cluster.  The
# persistent structures below carry solver state *across* ticks instead:
# a tick only touches jobs whose remaining work changed since the last
# solve (arrivals, jobs that ran) and lazily discards entries for jobs
# that completed or whose work moved on — O(Δ log J) per tick.
#
# The *output* is sparse too: on the fast-engine path a policy returns
# an :class:`AllocDelta` — only the rows whose allocation may have
# moved — instead of a dense length-n target vector, so a steady-state
# tick costs O(Δ) target traffic instead of an O(n) ``np.zeros`` plus a
# full-width ``target != w`` compare.  The completeness obligation is
# on the policy: every row whose correct target differs from the
# engine's current allocation must be listed (listing unchanged rows is
# allowed — the engine filters).  All built-in policies discharge it
# with the same argument: the rows they can ever grant live in the
# FIFO candidate prefix (whose membership is monotone for a live job),
# plus an explicitly tracked previous-winner set for the policies that
# grant outside the prefix (srtf, exploratory).
#
# Identity contract: every structure reproduces its fresh solver
# bit-for-bit (same float ops per entry, same (gain, arrival-order) heap
# tie-breaks), gated by the engine parity suites and the
# incremental-vs-fresh fuzz/hypothesis tests.  Entries are keyed by the
# job's *admission slot* (the fast engine's arrays are slot-stable:
# rows never move, so slot order == arrival order == the reference
# list order the tie-breaks are defined over).
# --------------------------------------------------------------------------


class AllocDelta:
    """Sparse allocation result (fast-engine path only).

    ``w[k]`` is the new worker count for the job at admission slot
    ``slots[k]``; every live job not listed keeps its current
    allocation.  A policy returning a delta must list every slot whose
    correct target differs from the engine's current allocation;
    listing rows that did not change is fine (the engine compares and
    filters), listing a dead slot is not.
    """

    __slots__ = ("slots", "w")

    def __init__(self, slots: np.ndarray, w: np.ndarray):
        self.slots = slots
        self.w = w

    def __repr__(self) -> str:
        return f"AllocDelta({self.slots.tolist()}, {self.w.tolist()})"


_EMPTY_DELTA_ARR = np.empty(0, np.int64)


def _delta_empty() -> AllocDelta:
    return AllocDelta(_EMPTY_DELTA_ARR, _EMPTY_DELTA_ARR)


class IncrementalContext:
    """Cross-tick solver state for one fast-engine run.

    The engine owns one instance per ``simulate`` call and refreshes
    ``alive``/``prefix`` before every solve; policies keep their
    persistent structures (gain-heaps, remaining-time heaps, explore
    cursors) in ``store``.  ``alive[s]`` says whether admission slot
    ``s`` still holds a live job; ``prefix(k)`` returns the slots of
    the first ``k`` live jobs in arrival order (the FIFO candidate
    prefix every seeded solver grants from), maintained incrementally
    by the engine so a call is an O(1) slice.  ``scratch(n)`` hands out
    a reused int64 buffer for the few places that still materialize a
    dense target (placement mode, dense-policy compatibility) so no
    per-solve ``np.zeros(n)`` survives on the engine path.  The
    reference oracle never builds a context, so every policy falls back
    to its fresh dense solver there — which is exactly what the parity
    gates compare against.
    """

    __slots__ = ("alive", "prefix", "pref_version", "store", "_scratch",
                 "_ones", "tel")

    def __init__(self):
        self.alive: np.ndarray | None = None
        self.prefix: Callable[[int], np.ndarray] | None = None
        # bumped by the engine whenever prefix *membership* changes (an
        # append below the cap, or a prefix death + refill) — the memo
        # key for saturated all-ones answers
        self.pref_version = 0
        self.store: dict[str, object] = {}
        self._scratch = np.empty(0, np.int64)
        self._ones = np.empty(0, np.int64)
        # telemetry counter registry (``telemetry.Registry``) or None
        # when telemetry is off — solvers count heap ops only when set
        self.tel = None

    def scratch(self, n: int) -> np.ndarray:
        """A reused int64 buffer of length ``n`` (contents arbitrary)."""
        if len(self._scratch) < n:
            self._scratch = np.empty(
                max(n, 2 * len(self._scratch), 64), np.int64)
        return self._scratch[:n]

    def ones(self, n: int) -> np.ndarray:
        """A reused all-ones int64 buffer (saturated deltas; callers
        must treat it as read-only)."""
        if len(self._ones) < n:
            self._ones = np.ones(max(n, 2 * len(self._ones), 64),
                                 np.int64)
        return self._ones[:n]


class _StampedGainHeap:
    """Generation-stamped persistent base heap shared by the doubling and
    Optimus solvers.

    Holds one w=1 gain entry per candidate-prefix job (the first
    ``min(n_live, capacity)`` live jobs — the only jobs a FIFO-seeded
    solver can ever grant workers; a live job's rank among live jobs
    never grows, so prefix membership is monotone under the full cluster
    capacity).  An entry ``(-gain, slot, 1, stamp)`` stays valid while
    the job's remaining work is unchanged; when it changes (the job ran)
    the per-slot stamp is bumped and a fresh entry pushed, the old one
    discarded lazily at pop time.  Per-solve cost is O(dirty + heap
    copy) instead of a full O(prefix) rebuild — and under saturation
    (prefix == capacity) the solve short-circuits to all-ones without
    touching the heap at all.
    """

    __slots__ = ("last_q", "stamp", "base", "sat_key",
                 "_tel_src", "_c_push", "_c_pop", "_c_dirty", "_c_reb")

    def __init__(self):
        self.last_q = np.full(64, np.nan)
        self.stamp = np.zeros(64, np.int64)
        self.base: list[tuple[float, int, int, int]] = []
        # (pref_version, n1) memo of the last saturated all-ones delta
        # (see _SatCache for why it never needs clearing)
        self.sat_key: tuple[int, int] | None = None
        # telemetry counter handles, bound once per registry: the solve
        # path flushes with plain attribute bumps instead of dict lookups
        self._tel_src = None

    def _tel_bind(self, tel) -> None:
        self._tel_src = tel
        self._c_push = tel.counter("heap.pushes")
        self._c_pop = tel.counter("heap.pops")
        self._c_dirty = tel.counter("heap.dirty_rows")
        self._c_reb = tel.counter("heap.rebuilds")

    def _grow_to(self, m: int) -> None:
        self.last_q = _grow_array(self.last_q, m, np.nan)
        self.stamp = _grow_array(self.stamp, m, 0)

    def _refresh(self, state: "AllocView", P: np.ndarray) -> None:
        """Bring the base heap up to date with prefix slots ``P``.

        Jobs whose remaining work changed since their entry was stamped
        (NaN-seeded, so new arrivals are dirty by construction) get a
        fresh w=1 entry; stale ones die by stamp at pop time.  When most
        of the prefix is dirty anyway (a saturated cluster doubles every
        prefix job every tick) a from-scratch rebuild is cheaper than
        accumulating one stale entry per push — the valid entry set is
        identical either way, except that a rebuild drops entries for
        jobs currently *outside* the prefix (the exploratory dynamic
        pool shrinks and regrows), so those are NaN-marked to count as
        dirty when they re-enter."""
        n1 = len(P)
        self._grow_to(int(P[-1]) + 1)
        q = state.remaining[P]
        dirty = np.nonzero(self.last_q[P] != q)[0]
        if not len(dirty):
            return
        tel = state.inc.tel if state.inc is not None else None
        if tel is not None:
            if tel is not self._tel_src:
                self._tel_bind(tel)
            self._c_dirty.n += len(dirty)
        rebuild = 2 * len(dirty) >= n1
        if rebuild:
            dirty = np.arange(n1)
            dslots = P
        else:
            dslots = P[dirty]
        self.stamp[dslots] += 1
        self.last_q[dslots] = q[dirty]
        rows = dslots if state.rows is None else state.rows[dslots]
        # the same vectorized w=1 gain pass as the fresh solvers, over
        # the dirty slice only
        gains = _gains_w1(q[dirty], state.tables, rows)
        caps_d = state.max_w[dslots]
        if state.max_w_clamp is not None:
            caps_d = np.minimum(caps_d, state.max_w_clamp)
        caps_d = caps_d.tolist()
        stamps = self.stamp[dslots].tolist()
        if rebuild:
            outside = {e[1] for e in self.base}
            self.base = [(-g, s, 1, stm)
                         for g, s, mw, stm in zip(gains, dslots.tolist(),
                                                  caps_d, stamps)
                         if g > 0.0 and 2 <= mw]
            heapq.heapify(self.base)
            outside.difference_update(dslots.tolist())
            for s in outside:
                self.last_q[s] = np.nan
            if tel is not None:
                self._c_reb.n += 1
                self._c_push.n += len(self.base)
            return
        base = self.base
        n0 = len(base)
        for g, s, mw, stm in zip(gains, dslots.tolist(), caps_d, stamps):
            if g > 0.0 and 2 <= mw:
                heapq.heappush(base, (-g, s, 1, stm))
        if tel is not None:
            self._c_push.n += len(base) - n0

    def _maybe_compact(self, ctx: IncrementalContext, n1: int) -> None:
        if len(self.base) <= 4 * n1 + 64:
            return
        stamp, alive = self.stamp, ctx.alive
        self.base = [e for e in self.base
                     if stamp[e[1]] == e[3] and alive[e[1]]]
        heapq.heapify(self.base)


class _PersistentDoublingHeap(_StampedGainHeap):
    """Incremental/sparse mode of ``doubling_heuristic_soa``: returns an
    :class:`AllocDelta` over the candidate prefix (delta completeness:
    any live job holding workers sits in the prefix, so every row that
    can change is listed)."""

    def solve(self, state: "AllocView", capacity: int,
              ctx: IncrementalContext,
              prefix: np.ndarray | None = None) -> AllocDelta:
        if prefix is None:
            n1 = min(state.n_live, capacity)
            if n1 == 0:
                return _delta_empty()
            P = ctx.prefix(n1)
        else:
            P = prefix
            n1 = len(P)
            if n1 == 0:
                return _delta_empty()
        W = state.tables.shape[1] - 1
        if n1 >= capacity or W < 2:
            # saturation: the w=1 FIFO seeding already spends the whole
            # cluster, so no doubling is ever feasible (used + w >
            # capacity for every entry) — the fresh solver provably
            # returns all-ones and the heap never needs touching.  This
            # is the steady state of every backlogged trace, so it is
            # memoized: with prefix membership unchanged the engine
            # already holds the all-ones answer and the solve is O(1).
            # (Only on the direct path — a caller passing its own
            # prefix, exploratory's dynamic pool, zeroes last-winner
            # rows by whatever the delta *lists*, so it needs the full
            # listing every time.)
            if prefix is None:
                key = (ctx.pref_version, n1)
                if key == self.sat_key:
                    return _delta_empty()
                self.sat_key = key
            return AllocDelta(P, ctx.ones(n1))
        self._refresh(state, P)
        self._maybe_compact(ctx, n1)
        heap = self.base.copy()       # a copy of a heap is a heap
        n0 = len(heap)
        pops = 0
        used = n1
        stamp = self.stamp
        pos_in = {s: i for i, s in enumerate(P.tolist())}
        head = [1] * n1
        tables, rows = state.tables, state.rows
        rem, maxw = state.remaining, state.max_w
        clamp = state.max_w_clamp
        while heap:
            neg_g, s, w, stm = heapq.heappop(heap)
            pops += 1
            if stamp[s] != stm:
                continue              # job ran since this entry was pushed
            idx = pos_in.get(s)
            if idx is None:
                continue              # completed, or outside this prefix
            if head[idx] != w:
                continue              # stale: job already doubled past w
            if used + w > capacity:
                continue    # never feasible again (used only grows)
            used += w
            w2 = 2 * w
            head[idx] = w2
            mw = int(maxw[s])
            if clamp is not None and clamp < mw:
                mw = clamp
            if 2 * w2 <= mw and used + w2 <= capacity and 2 * w2 <= W:
                table = tables[s if rows is None else rows[s]]
                gq = float(rem[s])
                g = (gq / max(float(table[w2]), 1e-12)
                     - gq / max(float(table[2 * w2]), 1e-12)) / w2
                if g > 0.0:
                    heapq.heappush(heap, (-g, s, w2, stm))
        tel = ctx.tel
        if tel is not None:
            if tel is not self._tel_src:
                self._tel_bind(tel)
            self._c_pop.n += pops
            self._c_push.n += len(heap) + pops - n0
        return AllocDelta(P, np.array(head, np.int64))


class _PersistentOptimusHeap(_StampedGainHeap):
    """Incremental/sparse mode of ``optimus_greedy_soa`` (+1 steps)."""

    def solve(self, state: "AllocView", capacity: int,
              ctx: IncrementalContext) -> AllocDelta:
        n1 = min(state.n_live, capacity)
        if n1 == 0:
            return _delta_empty()
        P = ctx.prefix(n1)
        W = state.tables.shape[1] - 1
        if n1 >= capacity or W < 2:
            # saturation: `while used < capacity` never iterates — the
            # fresh solver provably returns all-ones (memoized like the
            # doubling heap's saturated branch)
            key = (ctx.pref_version, n1)
            if key == self.sat_key:
                return _delta_empty()
            self.sat_key = key
            return AllocDelta(P, ctx.ones(n1))
        self._refresh(state, P)
        self._maybe_compact(ctx, n1)
        heap = self.base.copy()
        n0 = len(heap)
        pops = 0
        used = n1
        stamp = self.stamp
        pos_in = {s: i for i, s in enumerate(P.tolist())}
        head = [1] * n1
        tables, rows = state.tables, state.rows
        rem, maxw = state.remaining, state.max_w
        clamp = state.max_w_clamp
        while used < capacity and heap:
            neg_g, s, w, stm = heapq.heappop(heap)
            pops += 1
            if stamp[s] != stm:
                continue
            idx = pos_in.get(s)
            if idx is None:
                continue
            if head[idx] != w:
                continue                               # stale entry
            w1 = w + 1
            head[idx] = w1
            used += 1
            mw = int(maxw[s])
            if clamp is not None and clamp < mw:
                mw = clamp
            if w1 + 1 <= mw and w1 + 1 <= W:
                table = tables[s if rows is None else rows[s]]
                gq = float(rem[s])
                g = (gq / max(float(table[w1]), 1e-12)
                     - gq / max(float(table[w1 + 1]), 1e-12))
                if g > 0.0:
                    heapq.heappush(heap, (-g, s, w1, stm))
        tel = ctx.tel
        if tel is not None:
            if tel is not self._tel_src:
                self._tel_bind(tel)
            self._c_pop.n += pops
            self._c_push.n += len(heap) + pops - n0
        return AllocDelta(P, np.array(head, np.int64))


class _PersistentSRTFHeap:
    """Cross-tick remaining-time order for SRTF.

    The fresh SRTF pass argsorts every active job's best-case remaining
    time at every reallocation — O(J log J) per tick, *the* dominant cost
    of 10k-job traces (thousands of queued jobs whose remaining work
    never changes between ticks re-sorted tens of thousands of times).
    Here the order lives in a persistent min-heap of ``(t_best, slot,
    stamp)`` entries: a job's entry stays valid while it sits in the
    queue (w=0 ⇒ remaining unchanged ⇒ t_best unchanged); only new
    arrivals are pushed.  Last tick's winners (the ≤capacity jobs that
    actually ran) never re-enter the heap at all: they are merged
    against the heap head as a sorted candidate list in the grant loop
    below, and only the losers among them are re-pushed.  Per-job
    ``(w*, f_best)`` is static — cached per interned (speed-table row,
    cap) pair rather than recomputed per job per tick.  A steady-state
    shortcut (winner order unchanged, no deaths, no competitive
    arrival) answers the ~60% of solves where nothing moves with an
    empty delta without touching the heap.

    The delta lists last tick's winners (zeroed unless re-granted) plus
    this tick's winners — SRTF can grant *any* live job, so completeness
    comes from the tracked winner set, not prefix monotonicity.
    """

    __slots__ = ("f_best", "w_star", "stamp", "caps", "heap", "winners",
                 "seen", "rowcache", "_prev_np", "_prev_fnp", "_cap_left",
                 "_prev_deaths", "_tel_src", "_c_push", "_c_pop")

    def __init__(self):
        # per-slot state as plain Python lists: every access is a scalar
        # read/write on the solve hot path, where list indexing beats
        # ndarray scalar boxing several-fold.  ``caps`` is the clamped
        # per-job worker cap, computed once at registration — ``max_w``
        # is per-job static and ``max_w_clamp`` is a constant of the
        # policy wrapper (largest node of a fixed topology), so it
        # cannot drift between solves of one engine run.
        self.f_best: list[float] = []
        self.w_star: list[int] = []
        self.stamp: list[int] = []
        self.caps: list[int] = []
        self.heap: list[tuple[float, int, int]] = []
        self.winners: list[int] = []         # slots granted w>0 last solve
        self.seen = 0                        # slots below this are known
        self.rowcache: dict[tuple[int, int], tuple[int, float]] = {}
        # winners' slots / clamped f_best as pop-ordered ndarrays for
        # the steady-state order check (one gather + tolist per solve),
        # and the capacity left over by the last full solve (nonzero
        # disables the deep-backlog arrival shortcut until a full solve
        # runs)
        self._prev_np = _EMPTY_DELTA_ARR
        self._prev_fnp = np.empty(0)
        # telemetry counter handles, bound once per registry (solve is
        # the hottest policy path: flushes are plain attribute bumps)
        self._tel_src = None
        self._c_push = None
        self._c_pop = None
        self._cap_left = 1
        # slot-space dead count (hi - n_live) at the last solve: if it
        # has not moved, no row was removed since, so every winner is
        # still alive without touching the alive array (admissions keep
        # the difference fixed — they bump hi and n_live together)
        self._prev_deaths = -1

    def _grow_to(self, m: int) -> None:
        pad = m - len(self.stamp)
        if pad > 0:
            self.f_best.extend([0.0] * pad)
            self.w_star.extend([0] * pad)
            self.stamp.extend([0] * pad)
            self.caps.extend([0] * pad)

    def _cap_of(self, state: "AllocView", s: int, W: int) -> int:
        cap_i = int(state.max_w[s])
        clamp = state.max_w_clamp
        if clamp is not None and clamp < cap_i:
            cap_i = clamp
        return cap_i if cap_i < W else W

    def _best(self, state: "AllocView", s: int, W: int) -> tuple[int, float]:
        """(w*, f_best) for slot ``s``: the speed-maximizing feasible
        worker count — same argmax/tie semantics as the fresh masked
        pass, cached per (interned row, cap)."""
        cap_i = self._cap_of(state, s, W)
        row = s if state.rows is None else int(state.rows[s])
        key = (row, cap_i)
        got = self.rowcache.get(key)
        if got is None:
            tab = state.tables[row]
            w_star = int(np.argmax(tab[1:cap_i + 1])) + 1
            got = (w_star, float(tab[w_star]))
            self.rowcache[key] = got
        return got

    def solve(self, state: "AllocView", capacity: int,
              ctx: IncrementalContext) -> AllocDelta:
        alive = ctx.alive
        prev = self.winners
        W = state.tables.shape[1] - 1
        rem = state.remaining
        if state.n_live == 0 or W < 1:
            self.winners = []
            self._prev_np = _EMPTY_DELTA_ARR
            pa = [s for s in prev if alive[s]]
            if not pa:
                return _delta_empty()
            return AllocDelta(np.array(pa, np.int64),
                              np.zeros(len(pa), np.int64))
        # steady-state shortcut: no admissions since the last solve and
        # every winner still alive means only the winners' remaining
        # work moved — and only downward, so each winner still precedes
        # every queued entry it beat last time.  If the winners' (t,
        # slot) order is also unchanged, a fresh solve would pop the
        # same slots in the same order against the same capacity
        # sequence and grant the same workers: the engine's held
        # allocation is already the answer.  (One gather + ``tolist``,
        # then plain-float compares: this check runs on every solve.)
        steady = False
        t_last = 0.0
        if prev and state.hi - state.n_live == self._prev_deaths:
            # no removal since the last solve (the death count is exact:
            # only running jobs — winners — ever complete), so every
            # winner is alive; only the (t, slot) order needs checking
            tl = (rem.take(self._prev_np) / self._prev_fnp).tolist()
            t_pv = -math.inf
            s_pv = -1
            for i, tv in enumerate(tl):
                s = prev[i]
                if tv < t_pv or (tv == t_pv and s < s_pv):
                    break
                t_pv = tv
                s_pv = s
            else:
                steady = True
                t_last = t_pv
        if steady and self.seen >= state.hi:
            return _delta_empty()
        heap = self.heap
        tel = ctx.tel
        if tel is not None and tel is not self._tel_src:
            self._tel_src = tel
            self._c_push = tel.counter("heap.pushes")
            self._c_pop = tel.counter("heap.pops")
        n_push = 0
        n_pop = 0
        # a new arrival can only change the outcome if it beats the last
        # winner (new slots sort after every winner slot on ties) —
        # *and* there was no spare capacity it could claim outright
        new_lose = steady and self._cap_left == 0
        # register new arrivals (slots [seen, hi) — admitted since the
        # last solve; a slot that already died again is skipped for good)
        if self.seen < state.hi:
            self._grow_to(state.hi)
            caps_l = self.caps
            for s in range(self.seen, state.hi):
                if not alive[s]:
                    continue
                caps_l[s] = self._cap_of(state, s, W)
                w_star, f = self._best(state, s, W)
                self.w_star[s] = w_star
                # stored pre-clamped: every consumer divides by
                # max(f, 1e-12), so clamp once at registration
                fcl = max(f, 1e-12)
                self.f_best[s] = fcl
                stm = self.stamp[s] + 1
                self.stamp[s] = stm
                tb = float(rem[s]) / fcl
                if tb < t_last:
                    new_lose = False
                heapq.heappush(heap, (tb, s, stm))
                n_push += 1
            self.seen = state.hi
        if new_lose:
            # deep-backlog arrival: every new job sorts behind the
            # still-valid winner sequence and the cluster was already
            # spent — the fresh pop order is provably unchanged
            if tel is not None and n_push:
                self._c_push.n += n_push
            return _delta_empty()
        # Last tick's winners never sit in the big heap between solves —
        # re-pushing and re-popping them every solve costs ~2 log n heap
        # ops each, where a sorted candidate list merged against the
        # heap head costs none.  Their heap entries were consumed when
        # they were first granted (popped) and they are re-pushed only
        # if the grant loop below never reaches them, so for every
        # winner slot no live heap entry exists and the merge never
        # compares a slot against itself.
        stamp = self.stamp
        f_best = self.f_best
        caps_l = self.caps
        w_star_l = self.w_star
        cands = [(float(rem[s]) / f_best[s], s) for s in prev if alive[s]]
        cands.sort()
        nc = len(cands)
        ci = 0
        cap = capacity
        winners: list[int] = []
        ws: list[int] = []
        tables, rows = state.tables, state.rows
        while cap > 0:
            # valid heap head (lazy skip of dead / re-stamped entries)
            while heap:
                th, sh, stm = heap[0]
                if stamp[sh] == stm and alive[sh]:
                    break
                heapq.heappop(heap)
                n_pop += 1
            if ci < nc:
                tc, sc = cands[ci]
                if heap and (th < tc or (th == tc and sh < sc)):
                    s = sh
                    heapq.heappop(heap)
                    n_pop += 1
                else:
                    s = sc
                    ci += 1
            elif heap:
                s = sh
                heapq.heappop(heap)
                n_pop += 1
            else:
                break
            cap_i = caps_l[s]
            hi = cap_i if cap_i < cap else cap
            w = w_star_l[s]
            if w > hi:      # clipped by remaining capacity: re-derive
                row = s if rows is None else int(rows[s])
                w = int(np.argmax(tables[row, 1:hi + 1])) + 1
            winners.append(s)
            ws.append(w)
            cap -= w
        # candidates the grant loop never reached rejoin the queue with
        # their refreshed t — exactly the state a re-pushed-but-unpopped
        # entry would have held
        for j in range(ci, nc):
            tc, sc = cands[j]
            stm = stamp[sc] + 1
            stamp[sc] = stm
            heapq.heappush(heap, (tc, sc, stm))
            n_push += 1
        if tel is not None:
            if n_push:
                self._c_push.n += n_push
            if n_pop:
                self._c_pop.n += n_pop
        self.winners = winners
        fb = self.f_best
        self._prev_np = np.fromiter(winners, np.int64, len(winners))
        self._prev_fnp = np.array([fb[s] for s in winners])
        self._cap_left = cap
        self._prev_deaths = state.hi - state.n_live
        if len(heap) > 2 * state.n_live + 1024:
            self.heap = [e for e in heap
                         if stamp[e[1]] == e[2] and alive[e[1]]]
            heapq.heapify(self.heap)
        d = {s: 0 for s in prev if alive[s]}
        for s, w in zip(winners, ws):
            d[s] = w
        if not d:
            return _delta_empty()
        return AllocDelta(np.fromiter(d.keys(), np.int64, len(d)),
                          np.fromiter(d.values(), np.int64, len(d)))


def optimus_greedy_table(jobs: Sequence[TableJobTuple], capacity: int,
                         max_w: int | None = None) -> Alloc:
    """Optimus [8] over precomputed speed tables, with a lazy max-heap."""
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    heap: list[tuple[float, int, int]] = []   # (-gain, input index, w)

    def entry(idx: int, Q: float, table, w: int):
        """Heap entry for the +1 gain at w, or None if never selectable."""
        if max_w is not None and w + 1 > max_w:
            return None
        if w + 1 >= len(table):
            return None    # beyond the table bound => capacity-infeasible
        g = Q / max(table[w], 1e-12) - Q / max(table[w + 1], 1e-12)
        return (-g, idx, w) if g > 0.0 else None

    for idx, (jid, Q, table) in enumerate(jobs):
        if used < capacity:
            alloc[jid] = 1
            used += 1
            e = entry(idx, Q, table, 1)
            if e is not None:
                heap.append(e)
        else:
            alloc[jid] = 0
    heapq.heapify(heap)
    while used < capacity and heap:
        neg_g, idx, w = heapq.heappop(heap)
        jid, Q, table = jobs[idx]
        if alloc[jid] != w:
            continue                                   # stale entry
        alloc[jid] = w + 1
        used += 1
        e = entry(idx, Q, table, w + 1)
        if e is not None:
            heapq.heappush(heap, e)
    return alloc


def exact_dp_table(jobs: Sequence[TableJobTuple], capacity: int,
                   max_w: int | None = None,
                   powers_of_two: bool = False) -> Alloc:
    """Exact minimizer of Σ Q_j / f_j(w_j) by DP over capacity, from tables.

    Same DP (and identical tie-breaking) as the callable version; per-job
    costs Q/f(w) are precomputed once per job instead of re-evaluating the
    speed model in the O(J * C * W) inner loop.
    """
    jobs = list(jobs)
    J = len(jobs)
    wmax = min(max_w or capacity, capacity)
    choices = ([2 ** k for k in range(int(math.log2(wmax)) + 1)]
               if powers_of_two else list(range(1, wmax + 1)))
    assert J <= capacity, "exact_dp assumes every job can get >=1 worker (Z+)"
    dp = {0: (0.0, ())}
    for (jid, Q, table) in jobs:
        costs = [Q / max(table[w], 1e-12) for w in choices]
        ndp: dict[int, tuple[float, tuple]] = {}
        for c, (cost, chosen) in dp.items():
            for w, t in zip(choices, costs):
                nc = c + w
                if nc > capacity:
                    continue
                cand = (cost + t, chosen + (w,))
                if nc not in ndp or cand[0] < ndp[nc][0]:
                    ndp[nc] = cand
        dp = ndp
    best_cost, best_alloc = min(dp.values(), key=lambda kv: kv[0])
    return {jid: w for (jid, _, _), w in zip(jobs, best_alloc)}


# --------------------------------------------------------------------------
# Callable-based API: thin adapters over the table solvers.
# --------------------------------------------------------------------------

def doubling_heuristic(jobs: Sequence[JobTuple], capacity: int,
                       max_w=None) -> Alloc:
    bound = _table_bound(capacity, max_w)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return doubling_heuristic_table(tjobs, capacity, max_w)


def optimus_greedy(jobs: Sequence[JobTuple], capacity: int,
                   max_w: int | None = None) -> Alloc:
    bound = _table_bound(capacity, max_w)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return optimus_greedy_table(tjobs, capacity, max_w)


def exact_dp(jobs: Sequence[JobTuple], capacity: int,
             max_w: int | None = None, powers_of_two: bool = False) -> Alloc:
    # the DP normalizes with `max_w or capacity` (0 means unbounded, seed
    # semantics), so the sampled table must use the same bound
    bound = min(max_w or capacity, capacity)
    tjobs = [(jid, Q, _sample_table(f, bound)) for (jid, Q, f) in jobs]
    return exact_dp_table(tjobs, capacity, max_w, powers_of_two)


def fixed(jobs: Sequence[JobTuple], capacity: int, w_fixed: int) -> Alloc:
    """Every job requests w_fixed GPUs, granted FIFO while capacity lasts."""
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        w = min(w_fixed, capacity - used)
        w = w if w == w_fixed else 0    # all-or-nothing gang allocation
        alloc[jid] = w
        used += w
    return alloc


def total_time(jobs: Sequence[JobTuple], alloc: Alloc) -> float:
    tot = 0.0
    for (jid, Q, f) in jobs:
        w = alloc.get(jid, 0)
        if w > 0:
            tot += Q / max(f(w), 1e-12)
    return tot


# --------------------------------------------------------------------------
# Scheduling-policy registry.
#
# A policy is the cluster-level strategy Table 3 sweeps: given the active
# set (as SoA views — the representation both simulator engines share) and
# the ClusterModel, produce a worker-count target per job.  Policies are
# constructed exclusively through ``get_policy("spec")`` so every consumer
# (simulator engines, run_table3, benchmarks, tests) resolves strategy
# strings in exactly one place, with validation instead of str.split
# crashes deep in the event loop.
# --------------------------------------------------------------------------

# §7 simulation constants the exploratory policy and both engines share.
EXPLORE_SEGMENT = 150.0      # 2.5 minutes at each of 1, 2, 4, 8 (§7)
EXPLORE_WS = (1, 2, 4, 8)
RESCHEDULE_EVERY = 150.0     # == EXPLORE_SEGMENT (segment switches land
                             # exactly on reschedule ticks — load-bearing)


@dataclasses.dataclass
class AllocView:
    """Structure-of-arrays view of the active set.

    Two shapes, one field set:

    * **Dense** (``live is None`` — the reference oracle, ad-hoc callers,
      and non-``slotted`` policies): arrays hold exactly the active set
      in reference-list order (arrival order with in-place removals —
      the order is load-bearing for solver tie-breaks, FIFO fixed grants
      and explore-gang grants), and ``allocate`` returns a dense int64
      target aligned with them.
    * **Slotted** (``live`` is a bool array — the fast engine's view for
      ``slotted`` policies): every array is the engine's full
      admission-slot-indexed backing store.  Slots never move; dead
      slots keep stale values and are excluded by ``live``/``lo``/
      ``hi``/``n_live``.  Slot order *is* arrival order, so tie-breaks
      carry over unchanged.  ``allocate`` returns an
      :class:`AllocDelta` over absolute slots instead of a dense
      target.

    ``tables`` may be wider than the active set (the simulator's
    preallocated matrix); row ``rows[i]`` — or row i when ``rows`` is
    None — is job/slot i's speed table.
    """
    remaining: np.ndarray                # (n,) remaining work (epochs)
    tables: np.ndarray                   # 2-D speed-table matrix
    max_w: np.ndarray                    # (n,) per-job scale-out caps
    explore_started: np.ndarray          # (n,) explore-phase start, -inf
                                         # when the job never profiles
    rows: np.ndarray | None = None       # job i's row in `tables`
    # node-level snapshot (repro.core.placement.PlacementView) when the
    # cluster runs a placement engine; None on flat/legacy clusters
    placement: object | None = None
    # --- slotted-mode fields (fast engine only) ---
    live: np.ndarray | None = None       # bool per slot; None = dense mode
    lo: int = 0                          # first possibly-live slot
    hi: int = 0                          # one past the last admitted slot
    n_live: int = 0                      # number of live slots
    # pack wrapper's node-size cap on the slotted path: applied by the
    # solvers at point of use instead of materializing an O(n) clamped
    # copy of ``max_w``
    max_w_clamp: int | None = None
    # cross-tick solver state (fast engine only; None from the reference
    # oracle and ad-hoc callers, which makes every policy take its fresh
    # dense path — the identity baseline the parity gates compare
    # against)
    inc: IncrementalContext | None = None

    @property
    def n(self) -> int:
        return len(self.remaining)

    def row_of(self, i: int) -> np.ndarray:
        return self.tables[i if self.rows is None else self.rows[i]]


class SchedulingPolicy:
    """One cluster scheduling strategy.

    Subclasses set ``spec`` (the canonical string, e.g. ``"fixed_8"``) and
    implement :meth:`allocate`.  ``static`` declares that the target
    depends only on the active set's identity/order (not on remaining
    work), which lets the fast engine reuse a solve across pure reschedule
    ticks; ``explores`` makes the simulator stamp newly admitted jobs with
    an explore-phase start time.  ``slotted`` opts into the fast engine's
    slot-indexed views and the sparse :class:`AllocDelta` return contract
    (see :class:`AllocView`); policies that leave it False always receive
    dense views — the engine materializes them — so the ≤20-line
    dense-target recipe keeps working unmodified at any scale the dense
    gather can afford.
    """

    spec: str = "?"
    static: bool = False
    explores: bool = False
    slotted: bool = False

    def allocate(self, state: AllocView, cluster: ClusterModel,
                 now: float):
        """Dense views: return int64 worker counts aligned with
        ``state`` order.  Slotted views: return an :class:`AllocDelta`
        covering every slot whose target differs from the engine's
        current allocation."""
        raise NotImplementedError

    def validate(self, cluster: ClusterModel) -> None:
        """Reject cluster/policy combinations that can never make progress
        (called once by ``simulate`` before the event loop starts)."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.spec!r})"


@dataclasses.dataclass(frozen=True)
class _PolicyEntry:
    factory: Callable[[str | None], SchedulingPolicy]
    example: str            # a runnable spec, e.g. "fixed_8" for "fixed"


_POLICY_REGISTRY: dict[str, _PolicyEntry] = {}


def register_policy(name: str,
                    factory: Callable[[str | None], SchedulingPolicy],
                    example: str | None = None) -> None:
    """Register a policy under ``name``.

    ``factory(param)`` receives the parameter suffix of the spec string
    (``"8"`` for ``"fixed_8"``, None for a bare name) and must validate
    it.  ``example`` is a runnable spec for registry-wide parity gates
    (defaults to ``name`` — required for parameterized policies whose
    bare name is not runnable).
    """
    if name in _POLICY_REGISTRY:
        raise ValueError(f"policy {name!r} already registered")
    _POLICY_REGISTRY[name] = _PolicyEntry(factory, example or name)


def registered_policies() -> dict[str, str]:
    """``{name: runnable example spec}`` for every registered policy —
    the iteration surface for the CI parity gate and the docs."""
    return {n: e.example for n, e in sorted(_POLICY_REGISTRY.items())}


def get_policy(spec: str | SchedulingPolicy) -> SchedulingPolicy:
    """Resolve a strategy spec string into a policy instance.

    Exact registry names win (``"utility_greedy"``); otherwise the part
    after the last underscore is the policy parameter (``"fixed_8"`` ->
    ``fixed`` with k=8).  Malformed specs fail here, loudly, instead of
    dying inside ``str.split``/``int()`` deep in the engine.
    """
    if isinstance(spec, SchedulingPolicy):
        return spec
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"policy spec must be a non-empty string, "
                         f"got {spec!r}")
    base, param = _split_spec(_POLICY_REGISTRY, spec)
    entry = _POLICY_REGISTRY.get(base)
    if entry is None:
        raise ValueError(
            f"unknown scheduling policy {spec!r}; registered: "
            f"{', '.join(sorted(_POLICY_REGISTRY))}")
    return entry.factory(param)


def _split_spec(registry, spec: str) -> tuple[str, str | None]:
    """Longest registered prefix at an underscore boundary wins, so a
    name parameterized by another spec ("pack_utility_greedy" -> pack
    with param "utility_greedy") parses as well as "fixed_8".  Shared by
    the policy and admission-rule registries."""
    base, param = spec, None
    while base not in registry and "_" in base:
        base, tail = base.rsplit("_", 1)
        param = tail if param is None else f"{tail}_{param}"
    return base, param


def _no_param(name: str, param: str | None, noun: str = "policy") -> None:
    if param is not None:
        raise ValueError(f"{noun} {name!r} takes no parameter, "
                         f"got {name}_{param}")


def _int_param(name: str, param: str | None, example: str,
               noun: str = "policy") -> int:
    if param is None:
        raise ValueError(f"{noun} {name!r} needs an integer parameter, "
                         f"e.g. {example!r}")
    try:
        value = int(param)
    except ValueError:
        raise ValueError(f"{noun} parameter must be an integer, got "
                         f"{name}_{param}") from None
    if value < 1:
        raise ValueError(f"{noun} parameter must be >= 1, got "
                         f"{name}_{param}")
    return value


def _persistent(state: AllocView, key: str, cls):
    """The policy's persistent solver state for this engine run, or None
    when the view is dense (reference oracle, ad-hoc views) and the
    fresh solver must run instead."""
    if state.live is None or state.inc is None:
        return None
    store = state.inc.store
    inst = store.get(key)
    if inst is None:
        inst = store[key] = cls()
    return inst


class _SatCache:
    """Per-run saturation memo: the ``(pref_version, n1)`` of the last
    all-ones (or all-k) delta the engine already applied.  While the key
    is unchanged the prefix membership is unchanged, so the saturated
    answer is already the engine's held allocation and the solve is an
    empty delta.  Leaving saturation always bumps the version (it takes
    a completion, and runners live in the prefix), so a stale hit across
    a saturation gap is impossible and the memo never needs clearing."""

    __slots__ = ("key",)

    def __init__(self):
        self.key: tuple[int, int] | None = None


class DoublingPolicy(SchedulingPolicy):
    """``precompute`` (§7): resource models known up front, the §4.2
    doubling heuristic over the whole active set at every reallocation.
    Under the fast engine the solve is incremental — a persistent
    generation-stamped gain-heap carried across ticks, returning a
    sparse delta over the candidate prefix."""

    spec = "precompute"
    slotted = True

    def allocate(self, state, cluster, now):
        inc = _persistent(state, "doubling", _PersistentDoublingHeap)
        if inc is not None:
            return inc.solve(state, cluster.capacity, state.inc)
        return doubling_heuristic_soa(state.remaining, state.tables,
                                      cluster.capacity, max_w=state.max_w,
                                      rows=state.rows)


class _ExploreInc:
    """Persistent explorer/dynamic split for ``exploratory``.

    ``explore_started`` is stamped at admission, so it is non-decreasing
    over admission slots, and a job stops exploring for good once its
    last segment elapses — together the explorer set is a suffix of the
    slot space with a monotone left edge.  ``cursor`` (first slot still
    exploring) only ever moves right, so maintaining the split costs
    O(arrivals) over a whole run instead of two fresh O(n) masks per
    solve; the dynamic pool reuses one persistent doubling heap.
    """

    __slots__ = ("cursor", "winners", "heap")

    def __init__(self):
        self.cursor = 0
        self.winners: list[int] = []       # slots granted w>0 last solve
        self.heap = _PersistentDoublingHeap()


class ExploratoryPolicy(SchedulingPolicy):
    """``exploratory`` (§7): a new job spends 2.5 min at each of
    w = 1, 2, 4, 8 to collect the (w, f(w)) points eq. 5 needs, inside a
    gang reservation of min(8, remaining capacity); everyone else shares
    what is left through the doubling heuristic."""

    spec = "exploratory"
    explores = True
    slotted = True

    def allocate(self, state, cluster, now):
        if state.live is not None:
            return self._allocate_slotted(state, cluster, now)
        n = state.n
        cap = cluster.capacity
        target = np.zeros(n, np.int64)
        # -inf marks never-profiling jobs; keep them out of the floor
        # divide (inf // x is nan + a RuntimeWarning)
        profiling = np.isfinite(state.explore_started)
        seg = np.full(n, np.inf)
        if profiling.any():
            seg[profiling] = ((now - state.explore_started[profiling])
                              // EXPLORE_SEGMENT)
        explorer = seg < len(EXPLORE_WS)
        for i in np.nonzero(explorer)[0]:
            grant = min(8, cap)
            target[i] = min(EXPLORE_WS[int(seg[i])], grant)
            cap -= grant
        assert cap >= 0, "explore gang grants exceeded cluster capacity"
        dyn = np.nonzero(~explorer)[0]
        rows = dyn if state.rows is None else state.rows[dyn]
        target[dyn] = doubling_heuristic_soa(
            state.remaining[dyn], state.tables, cap,
            max_w=state.max_w[dyn], rows=rows)
        return target

    def _allocate_slotted(self, state, cluster, now):
        es = _persistent(state, "exploratory", _ExploreInc)
        started = state.explore_started
        alive = state.inc.alive
        hi = state.hi
        n_seg = len(EXPLORE_WS)
        cur = max(es.cursor, state.lo)
        # advance past slots done exploring — (now - t0) // 150 only
        # grows, so a slot walked past never explores again; -inf-stamped
        # slots (never profiled) are skipped the same way
        while cur < hi:
            t0 = float(started[cur])
            if math.isfinite(t0) and (now - t0) // EXPLORE_SEGMENT < n_seg:
                break
            cur += 1
        es.cursor = cur
        cap = cluster.capacity
        pairs_s: list[int] = []
        pairs_w: list[int] = []
        if cur < hi:
            E = np.nonzero(alive[cur:hi])[0] + cur
            # the cursor walk relies on admission-stamped (monotone)
            # explore starts; live slots past it are all mid-explore
            assert np.isfinite(started[E]).all(), (
                "slotted exploratory requires every admitted job "
                "explore-stamped (explores=True engine contract)")
            seg = ((now - started[E]) // EXPLORE_SEGMENT).astype(np.int64)
            for sg in seg.tolist():
                grant = min(8, cap)
                pairs_w.append(min(EXPLORE_WS[sg], grant))
                cap -= grant
            pairs_s = E.tolist()
            assert cap >= 0, "explore gang grants exceeded cluster capacity"
        n1 = min(state.n_live - len(pairs_s), cap)
        d = {s: 0 for s in es.winners if alive[s]}
        d.update(zip(pairs_s, pairs_w))
        winners = [s for s, w in zip(pairs_s, pairs_w) if w > 0]
        if n1 > 0:
            # every live non-explorer sits below the cursor, so the
            # global live prefix *is* the dynamic-pool prefix
            dd = es.heap.solve(state, cap, state.inc,
                               prefix=state.inc.prefix(n1))
            d.update(zip(dd.slots.tolist(), dd.w.tolist()))
            winners.extend(dd.slots.tolist())
        es.winners = winners
        if not d:
            return _delta_empty()
        return AllocDelta(np.fromiter(d.keys(), np.int64, len(d)),
                          np.fromiter(d.values(), np.int64, len(d)))


class FixedPolicy(SchedulingPolicy):
    """``fixed_k`` (§7 baselines): every job requests a constant gang of
    k workers, granted all-or-nothing FIFO while capacity lasts."""

    static = True
    slotted = True

    def __init__(self, k: int):
        self.k = k
        self.spec = f"fixed_{k}"

    def allocate(self, state, cluster, now):
        if state.live is not None:
            # the gang count capacity // k is constant, so a winner's
            # live rank only falls — every row that can change is in the
            # current prefix
            m = min(state.n_live, cluster.capacity // self.k)
            if m == 0:
                return _delta_empty()
            # the all-k answer is memoized on prefix membership (the
            # first m live slots): unchanged key == already applied
            sat = _persistent(state, "fixed_sat", _SatCache)
            key = (state.inc.pref_version, m)
            if key == sat.key:
                return _delta_empty()
            sat.key = key
            return AllocDelta(state.inc.prefix(m),
                              np.full(m, self.k, np.int64))
        return fixed_soa(state.n, cluster.capacity, self.k)

    def validate(self, cluster):
        if self.k > cluster.capacity:
            raise ValueError(
                f"{self.spec!r} can never run a job on a "
                f"{cluster.capacity}-GPU cluster (gang size must be in "
                f"[1, capacity])")


class SRTFPolicy(SchedulingPolicy):
    """Shortest-remaining-time-first: jobs ranked by their best-case
    remaining service time (Q / max_w f(w)); each, in that order, gets its
    speed-maximizing feasible worker count until capacity runs out.

    The classic size-based discipline the doubling heuristic implicitly
    approximates under contention — here as an explicit policy so the two
    can be compared head-to-head on heavy-tailed workloads.
    """

    spec = "srtf"
    slotted = True

    def allocate(self, state, cluster, now):
        inc = _persistent(state, "srtf", _PersistentSRTFHeap)
        if inc is not None:
            return inc.solve(state, cluster.capacity, state.inc)
        n = state.n
        cap = cluster.capacity
        target = np.zeros(n, np.int64)
        W = state.tables.shape[1] - 1
        # ranking pass, vectorized (this policy is non-static, so allocate
        # re-runs at every event — a per-job Python loop here would be the
        # slowest path in the engine on 1000-job traces).  Slicing to the
        # fleet-wide cap (max_w is 8..16 vs a 64-wide table) and avoiding
        # the fancy-index row copy cut the 1000-job trace from ~1.0 s to
        # ~0.5 s; the speed-argmax is precomputed per job and only
        # re-derived in the loop when the remaining capacity clips it
        # (clipping drops trailing columns only, so ties still resolve to
        # the same, earliest, w).
        tabs = (state.tables[:n] if state.rows is None
                else state.tables[state.rows])
        caps = np.minimum(state.max_w, W)
        wcap = min(int(caps.max()), W) if n else 0
        if wcap < 1:
            return target
        masked = np.where(np.arange(1, wcap + 1)[None, :] <= caps[:, None],
                          tabs[:, 1:wcap + 1], 0.0)
        w_star = np.argmax(masked, axis=1) + 1
        f_best = masked[np.arange(n), w_star - 1]
        t_best = state.remaining / np.maximum(f_best, 1e-12)
        w_star = w_star.tolist()
        # stable sort: FIFO order breaks remaining-time ties
        for i in np.argsort(t_best, kind="stable").tolist():
            if cap <= 0:
                break
            hi = min(int(caps[i]), cap)
            if hi < 1:
                continue
            w = w_star[i]
            if w > hi:      # clipped by remaining capacity: re-derive
                w = int(np.argmax(tabs[i, 1:hi + 1])) + 1
            target[i] = w
            cap -= w
        return target


class UtilityGreedyPolicy(SchedulingPolicy):
    """GADGET-style utility greedy (arXiv 2202.01158): grow the job whose
    next ring-doubling adds the most cluster *throughput* per GPU.

    Start everyone at w=1 (FIFO), then repeatedly double the job with the
    best marginal utility (f(2w) - f(w)) / w.  Unlike the paper's
    ``precompute`` gain (eq. 6), the utility is Q-independent — the policy
    maximizes aggregate epochs/sec rather than total completion time, so
    it is blind to job sizes (and ``static``: a pure reschedule tick with
    an unchanged active set reuses the previous solve).
    """

    spec = "utility_greedy"
    static = True
    slotted = True

    def allocate(self, state, cluster, now):
        capacity = cluster.capacity
        slotted = state.live is not None
        if slotted:
            n1 = min(state.n_live, capacity)
            if n1 == 0:
                return _delta_empty()
            if n1 >= capacity:
                # saturation: the FIFO w=1 seeding spends the cluster,
                # no double ever fits — all-ones without heap work,
                # memoized on prefix membership (see _SatCache)
                sat = _persistent(state, "utility_sat", _SatCache)
                key = (state.inc.pref_version, n1)
                if key == sat.key:
                    return _delta_empty()
                sat.key = key
                return AllocDelta(state.inc.prefix(n1),
                                  state.inc.ones(n1))
            P = state.inc.prefix(n1)
            slots = P.tolist()
            caps = state.max_w[P]
            if state.max_w_clamp is not None:
                caps = np.minimum(caps, state.max_w_clamp)
            caps = caps.tolist()
        else:
            n = state.n
            n1 = min(n, capacity)
            out = np.zeros(n, dtype=np.int64)
            if n1 == 0:
                return out
            # only the FIFO w=1 prefix can ever be granted workers: keep
            # the per-job Python materialization to that prefix (10k-job
            # traces queue thousands of jobs behind it)
            slots = list(range(n1))
            caps = state.max_w[:n1].tolist()
        head = [1] * n1
        used = n1
        W = state.tables.shape[1] - 1
        heap: list[tuple[float, int, int]] = []
        for i in range(n1):
            if 2 <= min(caps[i], W):
                table = state.row_of(slots[i])
                g = float(table[2]) - float(table[1])
                if g > 0.0:
                    heap.append((-g, i, 1))
        heapq.heapify(heap)
        n_push = len(heap)
        n_pop = 0
        while heap:
            neg_g, idx, w = heapq.heappop(heap)
            n_pop += 1
            if head[idx] != w:
                continue                  # stale: job already doubled past w
            if used + w > capacity:
                continue                  # never feasible again -> discard
            used += w
            w2 = 2 * w
            head[idx] = w2
            if 2 * w2 <= min(caps[idx], W) and used + w2 <= capacity:
                table = state.row_of(slots[idx])
                g = (float(table[2 * w2]) - float(table[w2])) / w2
                if g > 0.0:
                    heapq.heappush(heap, (-g, idx, w2))
                    n_push += 1
        tel = state.inc.tel if state.inc is not None else None
        if tel is not None:
            tel.counter("heap.pushes").inc(n_push)
            tel.counter("heap.pops").inc(n_pop)
        if slotted:
            return AllocDelta(P, np.array(head, np.int64))
        out[:n1] = head
        return out


class OptimusPolicy(SchedulingPolicy):
    """``optimus``: the Optimus [8] +1-greedy baseline as a cluster
    policy — grow the job whose next *single* worker buys the most
    completion-time reduction.  The §4.2 motivation's head-to-head rival
    (+1 greedy stalls at the power-of-two cliff where doubling steps
    over it); under the fast engine it shares the persistent
    gain-heap machinery with ``precompute``."""

    spec = "optimus"
    slotted = True

    def allocate(self, state, cluster, now):
        inc = _persistent(state, "optimus", _PersistentOptimusHeap)
        if inc is not None:
            return inc.solve(state, cluster.capacity, state.inc)
        return optimus_greedy_soa(state.remaining, state.tables,
                                  cluster.capacity, max_w=state.max_w,
                                  rows=state.rows)


class PackPolicy(SchedulingPolicy):
    """Placement-aware wrapper (``pack_<policy>``): clamp every job's
    scale-out cap to the largest node, so gangs never span the slow
    inter-node fabric — the ≤20-line recipe for making any registered
    policy topology-aware (the inner policy sees flat speed tables under
    a placement engine and would otherwise overestimate spanning rings).
    """

    def __init__(self, inner: SchedulingPolicy):
        self.inner = inner
        self.spec = f"pack_{inner.spec}"
        self.static = inner.static
        self.explores = inner.explores
        self.slotted = inner.slotted

    def allocate(self, state, cluster, now):
        node_cap = max(n.gpus for n in cluster.node_specs())
        if state.live is not None:
            # slotted: a scalar clamp the solvers apply at point of use
            # — no O(n) copy of the slot-wide max_w array per solve
            clamp = (node_cap if state.max_w_clamp is None
                     else min(state.max_w_clamp, node_cap))
            clamped = dataclasses.replace(state, max_w_clamp=clamp)
        else:
            clamped = dataclasses.replace(
                state, max_w=np.minimum(state.max_w, node_cap))
        return self.inner.allocate(clamped, cluster, now)

    def validate(self, cluster):
        self.inner.validate(cluster)


class RecoveryAwarePolicy(SchedulingPolicy):
    """``recovery_aware``: SRTF ranking with fault-aware grants.

    Two changes against blind srtf, both read off the PlacementView's
    node-health snapshot (fault injection, ``repro.core.faults``):

      * the total grant budget is the *surviving* (ok, non-draining)
        capacity, not the nameplate — grants the placement engine would
        clamp to dead nodes only churn restart freezes;
      * every gang is clamped to the largest healthy full-speed node, so
        gangs stay single-node: a node failure kills at most the gangs
        actually on it instead of every ring spanning it, and straggling
        (degraded) nodes are not sized into.

    Off-placement there is no node snapshot and the policy degrades to
    plain srtf ranking; on a fault-free placement cluster it packs like
    ``pack_srtf`` (largest-node clamp, no health mask).
    """

    spec = "recovery_aware"

    def allocate(self, state, cluster, now):
        if state.live is not None:
            # dense policy on a slotted view (ad-hoc callers, the
            # delta-vs-dense harness — the engines always hand dense
            # views): gather the live set and solve over it
            ls = np.flatnonzero(state.live[state.lo:state.hi]) + state.lo
            state = AllocView(
                remaining=state.remaining[ls], tables=state.tables,
                max_w=state.max_w[ls],
                explore_started=state.explore_started[ls],
                rows=ls if state.rows is None else state.rows[ls],
                placement=state.placement)
        n = state.n
        cap = cluster.capacity
        pv = state.placement
        node_cap = 0
        if pv is not None:
            gpus = pv.node_gpus
            if pv.ok is not None:
                healthy = pv.ok & ~pv.draining
                cap = min(cap, int(gpus[healthy].sum()))
                pick = healthy & (pv.speed_mult >= 1.0)
                if not pick.any():
                    pick = healthy
                node_cap = int(gpus[pick].max()) if pick.any() else 0
            else:
                node_cap = int(gpus.max())
        target = np.zeros(n, np.int64)
        if n == 0 or cap <= 0:
            return target
        W = state.tables.shape[1] - 1
        tabs = (state.tables[:n] if state.rows is None
                else state.tables[state.rows])
        caps = np.minimum(state.max_w, W)
        if node_cap:
            caps = np.minimum(caps, node_cap)
        wcap = min(int(caps.max()), W)
        if wcap < 1:
            return target
        masked = np.where(np.arange(1, wcap + 1)[None, :] <= caps[:, None],
                          tabs[:, 1:wcap + 1], 0.0)
        w_star = np.argmax(masked, axis=1) + 1
        f_best = masked[np.arange(n), w_star - 1]
        t_best = state.remaining / np.maximum(f_best, 1e-12)
        w_star = w_star.tolist()
        # stable sort: FIFO order breaks remaining-time ties (like srtf)
        for i in np.argsort(t_best, kind="stable").tolist():
            if cap <= 0:
                break
            hi = min(int(caps[i]), cap)
            if hi < 1:
                continue
            w = w_star[i]
            if w > hi:      # clipped by remaining budget: re-derive
                w = int(np.argmax(tabs[i, 1:hi + 1])) + 1
            target[i] = w
            cap -= w
        return target


def _parameterless(name: str, cls: type[SchedulingPolicy]):
    def factory(param: str | None) -> SchedulingPolicy:
        _no_param(name, param)
        return cls()
    return factory


register_policy("precompute", _parameterless("precompute", DoublingPolicy))
register_policy("exploratory",
                _parameterless("exploratory", ExploratoryPolicy))
register_policy("fixed",
                lambda p: FixedPolicy(_int_param("fixed", p, "fixed_8")),
                example="fixed_8")
register_policy("srtf", _parameterless("srtf", SRTFPolicy))
register_policy("optimus", _parameterless("optimus", OptimusPolicy))
register_policy("utility_greedy",
                _parameterless("utility_greedy", UtilityGreedyPolicy))
register_policy("recovery_aware",
                _parameterless("recovery_aware", RecoveryAwarePolicy))


def _pack_factory(param: str | None) -> SchedulingPolicy:
    if param is None:
        raise ValueError("policy 'pack' wraps another policy spec, "
                         "e.g. 'pack_srtf' or 'pack_precompute'")
    return PackPolicy(get_policy(param))


register_policy("pack", _pack_factory, example="pack_srtf")
