"""Resource allocation — paper §4.

The problem (§4.1):   min Σ_j t_j,  t_j = Q_j / f_j(w_j),
                      Σ_j w_j <= C,  w_j in Z+           (NP-hard, non-convex)

Solvers:
  * ``doubling_heuristic``  — §4.2, the paper's contribution: start every job
    at 1 worker, repeatedly *double* the job with the best average marginal
    gain (Q/f(w) - Q/f(2w)) / w.  Doubling steps over the power-of-two
    cliff (8 -> 9 is a per-GPU regression under doubling-halving; 8 -> 16 is
    not), where +1 greedy stalls.
  * ``optimus_greedy``      — the Optimus baseline: +1 worker at a time.
  * ``exact_dp``            — exact DP over worker counts (validation).
  * ``fixed``               — every job requests a constant w (§7 baselines).

All solvers take jobs as (job_id, Q, speed_fn) and return {job_id: w}.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

Alloc = dict[int, int]
JobTuple = tuple[int, float, Callable[[int], float]]  # (id, Q, speed_fn)


def _gain_double(Q: float, f, w: int) -> float:
    """Average marginal gain of doubling w -> 2w, per added GPU (eq. 6)."""
    t_now = Q / max(f(w), 1e-12)
    t_next = Q / max(f(2 * w), 1e-12)
    return (t_now - t_next) / w


def doubling_heuristic(jobs: Sequence[JobTuple], capacity: int,
                       max_w: int | None = None) -> Alloc:
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    # 1 worker to every job (FIFO when oversubscribed)
    for (jid, _, _) in jobs:
        if used < capacity:
            alloc[jid] = 1
            used += 1
        else:
            alloc[jid] = 0
    # doubling by best average marginal gain
    while True:
        best, best_gain = None, 0.0
        for (jid, Q, f) in jobs:
            w = alloc[jid]
            if w == 0:
                continue
            if max_w is not None and 2 * w > max_w:
                continue
            if used + w > capacity:   # doubling adds w more workers
                continue
            g = _gain_double(Q, f, w)
            if g > best_gain:
                best, best_gain = jid, g
        if best is None:
            return alloc
        used += alloc[best]
        alloc[best] *= 2


def optimus_greedy(jobs: Sequence[JobTuple], capacity: int,
                   max_w: int | None = None) -> Alloc:
    """Optimus [8]: add the single best projected worker at each step."""
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        if used < capacity:
            alloc[jid] = 1
            used += 1
        else:
            alloc[jid] = 0
    while used < capacity:
        best, best_gain = None, 0.0
        for (jid, Q, f) in jobs:
            w = alloc[jid]
            if w == 0:
                continue
            if max_w is not None and w + 1 > max_w:
                continue
            g = Q / max(f(w), 1e-12) - Q / max(f(w + 1), 1e-12)
            if g > best_gain:
                best, best_gain = jid, g
        if best is None:
            return alloc
        alloc[best] += 1
        used += 1
    return alloc


def fixed(jobs: Sequence[JobTuple], capacity: int, w_fixed: int) -> Alloc:
    """Every job requests w_fixed GPUs, granted FIFO while capacity lasts."""
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        w = min(w_fixed, capacity - used)
        w = w if w == w_fixed else 0    # all-or-nothing gang allocation
        alloc[jid] = w
        used += w
    return alloc


def exact_dp(jobs: Sequence[JobTuple], capacity: int,
             max_w: int | None = None, powers_of_two: bool = False) -> Alloc:
    """Exact minimizer of Σ Q_j / f_j(w_j) by DP over capacity.

    Exponential-free: O(J * C * W). Small instances only (validation).
    """
    jobs = list(jobs)
    J = len(jobs)
    wmax = min(max_w or capacity, capacity)
    choices = ([2 ** k for k in range(int(math.log2(wmax)) + 1)]
               if powers_of_two else list(range(1, wmax + 1)))
    assert J <= capacity, "exact_dp assumes every job can get >=1 worker (Z+)"
    # dp[c] = (cost, alloc-tuple) best using first j jobs and c workers
    dp = {0: (0.0, ())}
    for (jid, Q, f) in jobs:
        ndp: dict[int, tuple[float, tuple]] = {}
        for c, (cost, chosen) in dp.items():
            for w in choices:
                nc = c + w
                if nc > capacity:
                    continue
                t = 0.0 if w == 0 else Q / max(f(w), 1e-12)
                cand = (cost + t, chosen + (w,))
                if nc not in ndp or cand[0] < ndp[nc][0]:
                    ndp[nc] = cand
        dp = ndp
    best_cost, best_alloc = min(dp.values(), key=lambda kv: kv[0])
    return {jid: w for (jid, _, _), w in zip(jobs, best_alloc)}


def total_time(jobs: Sequence[JobTuple], alloc: Alloc) -> float:
    tot = 0.0
    for (jid, Q, f) in jobs:
        w = alloc.get(jid, 0)
        if w > 0:
            tot += Q / max(f(w), 1e-12)
    return tot
