"""Parity oracle — the seed implementations, kept with the seed's cost
profile.

Everything in this module exists to *check* (and benchmark against) the
fast paths, never to run them:

  * ``doubling_heuristic_ref`` / ``optimus_greedy_ref`` / ``exact_dp_ref``
    — the pre-table O(J)-rescan solvers over (job_id, Q, speed_fn)
    callables.  The fast table/SoA solvers in ``repro.core.scheduler``
    must stay allocation-for-allocation identical to these (asserted by
    tests/test_scheduler_tables.py and ``bench_scheduler.py --check``).
  * ``simulate_reference`` — the seed §7 event loop (O(J) candidate
    rescans, scalar ``JobSpec.speed`` calls throughout, list pops for
    arrivals).  ``simulate(..., engine="reference")`` dispatches here; the
    SoA engine must produce bit-identical completion times, and the
    benchmark's ≥20× speedup floor is measured against this loop.

For the paper's own strategies (precompute / exploratory / fixed_k) the
loop allocates through the ``*_ref`` solvers — the seed code path,
verbatim.  Any *other* registered policy is adapted onto its own
``allocate()`` over views built per solve, so the trajectory bookkeeping
(event ordering, scalar progress arithmetic) is still independently
exercised for new policies even though the allocator is shared.

Cluster awareness mirrors the fast engine exactly: a non-flat topology
swaps each job's scalar speed callable for a lookup into its
cluster-scaled speed table, and the GADGET-style contention factor
multiplies the speed of every concurrently-communicating (w >= 2) job.
Flat homogeneous clusters skip both branches and run the seed arithmetic
untouched.

(The only change since the seed: ``doubling_heuristic_ref`` accepts
per-job caps via ``_caps``, extended in lockstep with the fast solvers so
parity stays meaningful on heterogeneous fleets.)
"""
from __future__ import annotations

import dataclasses
import math
from time import perf_counter
from typing import Sequence

import numpy as np

from repro.collectives.cost import ClusterModel
from repro.core import scheduler as sched
from repro.core import telemetry as _tele
from repro.core.jobs import JobSpec
from repro.core.scheduler import (Alloc, EXPLORE_SEGMENT, EXPLORE_WS,
                                  JobTuple, RESCHEDULE_EVERY, _caps,
                                  _gain_double)


def doubling_heuristic_ref(jobs: Sequence[JobTuple], capacity: int,
                           max_w=None) -> Alloc:
    jobs = list(jobs)
    caps = _caps(max_w, len(jobs))   # scalar or per-job, like the fast path
    alloc: Alloc = {}
    used = 0
    # 1 worker to every job (FIFO when oversubscribed)
    for (jid, _, _) in jobs:
        if used < capacity:
            alloc[jid] = 1
            used += 1
        else:
            alloc[jid] = 0
    # doubling by best average marginal gain
    while True:
        best, best_gain = None, 0.0
        for idx, (jid, Q, f) in enumerate(jobs):
            w = alloc[jid]
            if w == 0:
                continue
            mw = caps[idx]
            if mw is not None and 2 * w > mw:
                continue
            if used + w > capacity:   # doubling adds w more workers
                continue
            g = _gain_double(Q, f, w)
            if g > best_gain:
                best, best_gain = jid, g
        if best is None:
            return alloc
        used += alloc[best]
        alloc[best] *= 2


def optimus_greedy_ref(jobs: Sequence[JobTuple], capacity: int,
                       max_w: int | None = None) -> Alloc:
    jobs = list(jobs)
    alloc: Alloc = {}
    used = 0
    for (jid, _, _) in jobs:
        if used < capacity:
            alloc[jid] = 1
            used += 1
        else:
            alloc[jid] = 0
    while used < capacity:
        best, best_gain = None, 0.0
        for (jid, Q, f) in jobs:
            w = alloc[jid]
            if w == 0:
                continue
            if max_w is not None and w + 1 > max_w:
                continue
            g = Q / max(f(w), 1e-12) - Q / max(f(w + 1), 1e-12)
            if g > best_gain:
                best, best_gain = jid, g
        if best is None:
            return alloc
        alloc[best] += 1
        used += 1
    return alloc


def exact_dp_ref(jobs: Sequence[JobTuple], capacity: int,
                 max_w: int | None = None,
                 powers_of_two: bool = False) -> Alloc:
    jobs = list(jobs)
    J = len(jobs)
    wmax = min(max_w or capacity, capacity)
    choices = ([2 ** k for k in range(int(math.log2(wmax)) + 1)]
               if powers_of_two else list(range(1, wmax + 1)))
    assert J <= capacity, "exact_dp assumes every job can get >=1 worker (Z+)"
    dp = {0: (0.0, ())}
    for (jid, Q, f) in jobs:
        ndp: dict[int, tuple[float, tuple]] = {}
        for c, (cost, chosen) in dp.items():
            for w in choices:
                nc = c + w
                if nc > capacity:
                    continue
                t = 0.0 if w == 0 else Q / max(f(w), 1e-12)
                cand = (cost + t, chosen + (w,))
                if nc not in ndp or cand[0] < ndp[nc][0]:
                    ndp[nc] = cand
        dp = ndp
    best_cost, best_alloc = min(dp.values(), key=lambda kv: kv[0])
    return {jid: w for (jid, _, _), w in zip(jobs, best_alloc)}


# --------------------------------------------------------------------------
# The seed §7 event loop.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Active:
    spec: JobSpec
    remaining: float              # epochs
    w: int = 0
    frozen_until: float = 0.0     # restart pause
    explore_started: float | None = None
    # scalar f(w): the job's own ``spec.speed`` on a flat cluster (the
    # seed cost profile), a cluster-scaled table lookup on a topology
    speed_fn: object = None
    # placement-engine state: speed multiplier for the current gang
    # assignment and its actual spanning flag (1.0 / False off-placement)
    place_factor: float = 1.0
    spans: bool = False

    def __post_init__(self):
        if self.speed_fn is None:
            self.speed_fn = self.spec.speed

    def explore_w(self, now: float) -> int | None:
        """Worker count dictated by the explore phase, or None if done."""
        if self.explore_started is None:
            return None
        seg = int((now - self.explore_started) // EXPLORE_SEGMENT)
        if seg >= len(EXPLORE_WS):
            return None
        return EXPLORE_WS[seg]

    def speed(self, now: float) -> float:
        if now < self.frozen_until or self.w <= 0:
            return 0.0
        s = self.speed_fn(self.w)
        # guarded multiply: the flat seed arithmetic stays byte-for-byte
        # untouched (x * 1.0 would be exact too, but why touch it)
        return s if self.place_factor == 1.0 else s * self.place_factor


def _explore_grants(active: list[_Active], capacity: int, now: float,
                    alloc: dict[int, int], dynamic: list[_Active]) -> int:
    """Grant explore-phase jobs their gang reservation; returns leftover cap.

    Each profiling job reserves a gang of ``min(8, remaining capacity)``
    GPUs (clamped — the old all-or-nothing 8 grant handed later explorers
    exactly 0 and kept them out of the dynamic pool, silently starving
    them) and runs its schedule-dictated w inside that reservation.
    """
    cap = capacity
    for a in active:
        ew = a.explore_w(now)
        if ew is not None:
            grant = min(8, cap)
            alloc[a.spec.job_id] = min(ew, grant)
            cap -= grant
        else:
            dynamic.append(a)
    return cap


def _view_of(active: list[_Active], cluster: ClusterModel,
             placement=None) -> sched.AllocView:
    """SoA views over an ``_Active`` list, built per solve (oracle only)."""
    return sched.AllocView(
        remaining=np.array([a.remaining for a in active]),
        tables=np.stack([np.asarray(a.spec.speed_table(cluster))
                         for a in active]),
        max_w=np.array([a.spec.max_w for a in active], np.int64),
        explore_started=np.array(
            [-np.inf if a.explore_started is None else a.explore_started
             for a in active]),
        placement=placement)


def _allocate_seed(policy: sched.SchedulingPolicy, active: list[_Active],
                   capacity: int, now: float) -> dict[int, int]:
    """Seed allocation path for the paper's own strategies: callable-based
    ``*_ref`` solvers, the original cost profile."""
    if isinstance(policy, sched.FixedPolicy):
        tuples = [(a.spec.job_id, a.remaining, a.speed_fn) for a in active]
        return sched.fixed(tuples, capacity, policy.k)

    alloc: dict[int, int] = {}
    dynamic: list[_Active] = []
    if isinstance(policy, sched.ExploratoryPolicy):
        cap = _explore_grants(active, capacity, now, alloc, dynamic)
    else:  # precompute: all jobs schedulable immediately
        cap = capacity
        dynamic = list(active)
    tuples = [(a.spec.job_id, a.remaining, a.speed_fn) for a in dynamic]
    alloc.update(doubling_heuristic_ref(
        tuples, cap, max_w=[a.spec.max_w for a in dynamic]))
    return alloc


_SEED_POLICIES = (sched.DoublingPolicy, sched.ExploratoryPolicy,
                  sched.FixedPolicy)


def simulate_reference(jobs: list[JobSpec], cluster: ClusterModel,
                       policy: sched.SchedulingPolicy,
                       tel: object = _tele.NULL):
    """The pre-table event loop — the trajectory oracle.

    Must stay behaviorally identical to the SoA engine
    (``simulator._simulate_table``), asserted by tests and
    benchmarks/bench_scheduler.py.  Telemetry events mirror the fast
    engine's: the emitted *set* per timestamp is identical (trajectory
    parity), so the metrics rollup — an order-insensitive integral over
    ``dt > 0`` spans — is bitwise-equal between engines.
    """
    from repro.core.simulator import SimResult

    capacity = cluster.capacity
    penalty = cluster.contention_penalty
    flat_fabric = cluster.gpus_per_node is None
    peng = None
    if cluster.placement is not None:
        from repro.core.placement import PlacementEngine
        peng = PlacementEngine(cluster)
    rec = tel.recorder(policy.spec, capacity, len(jobs),
                       cluster.gpus_per_node or 0)
    rec_on = rec.on
    # solve-timer handle hoisted out of the event loop (bound method:
    # one call per reallocation instead of two attribute chases + call)
    t_solve_add = rec.t_solve.add if rec_on else None
    if peng is not None:
        peng.rec = rec
    pending = sorted(jobs, key=lambda j: j.arrival)
    # Fault injection: the same deterministic incident tape as the fast
    # engine — same model, same seed, same horizon, so the schedules are
    # bit-identical by construction (FaultModel.schedule is pure).
    fsched: tuple = ()
    ckpt = None
    if cluster.faults is not None:
        from repro.core.faults import CheckpointPolicy, get_fault_model
        horizon = pending[-1].arrival if pending else 0.0
        fsched = get_fault_model(cluster.faults).schedule(
            cluster, cluster.fault_seed, horizon)
        ckpt = CheckpointPolicy(
            interval=(cluster.checkpoint_interval
                      if cluster.checkpoint_interval is not None
                      else CheckpointPolicy.interval),
            restart_cost=cluster.restart_cost)
    nf = len(fsched)
    fi = 0
    requeue_rem: dict[int, float] = {}
    evictions = 0
    active: list[_Active] = []
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    delayed: list[JobSpec] = []
    rejected: list[int] = []
    now = 0.0
    peak = 0
    next_resched = 0.0
    seed_policy = isinstance(policy, _SEED_POLICIES)

    def _admit(j: JobSpec, now: float) -> None:
        a = _Active(spec=j, remaining=j.epochs)
        rr = requeue_rem.pop(j.job_id, None)
        if rr is not None:
            # evicted-then-readmitted: resume from rolled-back progress
            a.remaining = rr
        if not flat_fabric or peng is not None:
            # placement engines run over the *flat* table (speed_table
            # returns it when cluster.placement is set) and scale by the
            # per-assignment factor instead of baked spanning rows
            table = j.speed_table(cluster)
            a.speed_fn = lambda w, t=table: float(t[w])
        if policy.explores:
            a.explore_started = now
        if peng is not None:
            peng.register(j)
        active.append(a)

    def apply_alloc(now: float):
        if seed_policy:
            target = _allocate_seed(policy, active, capacity, now)
        else:
            soa = policy.allocate(
                _view_of(active, cluster,
                         None if peng is None else peng.view()),
                cluster, now)
            target = {a.spec.job_id: int(w) for a, w in zip(active, soa)}
        if peng is None:
            if rec_on:
                nchg = sum(1 for a in active
                           if target.get(a.spec.job_id, 0) != a.w)
                if nchg:
                    rec.solve(now, nchg, False, len(active))
                else:
                    rec.solve_reused()
            for a in active:
                w_new = target.get(a.spec.job_id, 0)
                if w_new != a.w:
                    if rec_on:
                        rec.alloc(now, a.spec.job_id, a.w, w_new)
                    a.w = w_new
                    if w_new > 0:
                        a.frozen_until = now + cluster.restart_cost
                        if rec_on:
                            rec.freeze(now, a.spec.job_id, a.frozen_until)
            return
        ids = [a.spec.job_id for a in active]
        tvec = [target.get(jid, 0) for jid in ids]
        changed = [i for i, a in enumerate(active) if tvec[i] != a.w]
        if rec_on:
            if changed:
                rec.solve(now, len(changed), False, len(active))
            else:
                rec.solve_reused()
        upd, factors, spans = peng.apply(ids, tvec, changed, now)
        # alloc events fire after apply (mirrors the fast engine): the
        # fault clamp can shrink tvec entries in-place, and the logged
        # width must be the grant the gang actually got
        if rec_on:
            for i in changed:
                rec.alloc(now, active[i].spec.job_id, active[i].w, tvec[i])
        for i, a in enumerate(active):
            a.w = tvec[i]
        for pos, f, sp in zip(upd.tolist(), factors.tolist(),
                              spans.tolist()):
            a = active[pos]
            a.place_factor = f
            a.spans = sp
            if a.w > 0:
                a.frozen_until = now + cluster.restart_cost
                if rec_on:
                    rec.freeze(now, a.spec.job_id, a.frozen_until)
        # also freeze explore-phase jobs at segment switches implicitly via
        # reschedule events (RESCHEDULE_EVERY == EXPLORE_SEGMENT).

    while pending or active or delayed:
        # --- next event time -------------------------------------------
        # next_resched is always a candidate, so the list is never empty
        fac = 1.0
        if penalty:
            if peng is not None:
                fac = cluster.contention_factor(
                    sum(1 for a in active if a.spans))
            else:
                fac = cluster.contention_factor(
                    sum(1 for a in active if a.w >= 2))
        t_candidates = [next_resched]
        if pending:
            t_candidates.append(pending[0].arrival)
        if fi < nf:
            t_candidates.append(fsched[fi].t)
        for a in active:
            s = a.speed(now)
            if s > 0:
                if fac != 1.0 and (a.spans if peng is not None
                                   else a.w >= 2):
                    s *= fac
                t_candidates.append(max(now, a.frozen_until)
                                    + a.remaining / s)
            elif a.w > 0 and a.frozen_until > now:
                t_candidates.append(a.frozen_until)
        t_next = max(now, min(t_candidates))

        # --- advance progress -------------------------------------------
        for a in active:
            run_from = max(now, a.frozen_until)
            dt = max(0.0, t_next - run_from)
            s = a.speed_fn(a.w) if a.w > 0 else 0.0
            if a.place_factor != 1.0:
                s *= a.place_factor
            if fac != 1.0 and (a.spans if peng is not None else a.w >= 2):
                s *= fac
            a.remaining -= dt * s

        now = t_next

        # --- completions -------------------------------------------------
        finished = [a for a in active if a.remaining <= 1e-9]
        for a in finished:
            done[a.spec.job_id] = now
            active.remove(a)
            if peng is not None:
                peng.release(a.spec.job_id)
            if rec_on:
                rec.complete(now, a.spec.job_id)

        # --- faults ------------------------------------------------------
        # mirrors the fast engine exactly: incidents fire after
        # completions, before arrivals; victims evict in active-list
        # order (== the fast engine's ascending live slots) and re-enter
        # through the normal admission path
        faulted = False
        while fi < nf and fsched[fi].t <= now + 1e-9:
            fe = fsched[fi]
            fi += 1
            faulted = True
            if rec_on:
                rec.fault(now, fe.node, fe.kind)
            if fe.kind == "fail":
                victims = peng.fail(fe.node)
                if victims:
                    vset = set(victims)
                    vact = [a for a in active if a.spec.job_id in vset]
                    evicted = []
                    for a in vact:
                        done_p = a.spec.epochs - a.remaining
                        lost = ckpt.lost_progress(done_p)
                        evicted.append(
                            (a.spec.job_id, a.spec, a.remaining + lost,
                             lost,
                             lost / done_p if done_p > 0.0 else 0.0))
                        active.remove(a)
                    evictions += len(vact)
                    for jid, spec, new_rem, lost, lost_frac in evicted:
                        if rec_on:
                            rec.evict(now, jid, fe.node, lost, lost_frac)
                        requeue_rem[jid] = new_rem
                        verdict = peng.admit(spec, len(active),
                                             len(delayed), now)
                        if verdict == "admit":
                            _admit(spec, now)
                            if rec_on:
                                rec.recover(now, jid)
                        elif verdict == "reject":
                            requeue_rem.pop(jid)
                            rejected.append(jid)
                            if rec_on:
                                rec.reject(now, jid)
                        else:
                            delayed.append(spec)
                            if rec_on:
                                rec.delay(now, jid)
            elif fe.kind == "drain":
                peng.drain(fe.node)
            elif fe.kind == "recover":
                peng.recover(fe.node)
            else:
                peng.degrade(fe.node, fe.factor)

        # --- arrivals ----------------------------------------------------
        arrived = False
        if delayed:
            still: list[JobSpec] = []
            for j in delayed:
                verdict = peng.admit(j, len(active), len(still), now)
                if verdict == "admit":
                    _admit(j, now)
                    arrived = True
                    if rec_on:
                        rec.admit(now, j.job_id)
                elif verdict == "reject":
                    rejected.append(j.job_id)
                    if rec_on:
                        rec.reject(now, j.job_id)
                else:
                    still.append(j)
            if still and not arrived and not active and not pending:
                raise RuntimeError(
                    f"admission rule {cluster.admission!r} stalled: "
                    f"{len(still)} delayed jobs on an idle cluster")
            delayed = still
        while pending and pending[0].arrival <= now + 1e-9:
            j = pending.pop(0)
            if rec_on:
                rec.submit(now, j.job_id, j.arrival)
            if peng is not None:
                verdict = peng.admit(j, len(active), len(delayed), now)
                if verdict == "delay":
                    delayed.append(j)
                    if rec_on:
                        rec.delay(now, j.job_id)
                    continue
                if verdict == "reject":
                    rejected.append(j.job_id)
                    if rec_on:
                        rec.reject(now, j.job_id)
                    continue
            _admit(j, now)
            arrived = True
            if rec_on:
                rec.admit(now, j.job_id)

        peak = max(peak, len(active))

        # --- reallocation ------------------------------------------------
        if arrived or finished or faulted or now + 1e-9 >= next_resched:
            if active:
                if rec_on:
                    _t0 = perf_counter()
                    apply_alloc(now)
                    t_solve_add(perf_counter() - _t0)
                else:
                    apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY

    return SimResult(strategy=policy.spec, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak,
                     rejected=tuple(rejected),
                     migrations=0 if peng is None else peng.migrations,
                     evictions=evictions,
                     telemetry=rec.finish(now))
