"""Cluster scheduler simulation — paper §7.

Event-driven simulation of a C-GPU cluster with Poisson job arrivals.
Strategies are :class:`repro.core.scheduler.SchedulingPolicy` instances
resolved through the policy registry (``scheduler.get_policy``): the
paper's Table-3 set (``precompute``, ``exploratory``, ``fixed_k``) plus
any registered extension (``srtf``, ``utility_greedy``, ...).
Reallocation happens at arrivals, completions and periodic intervals;
every allocation change costs the measured checkpoint-stop-restart pause
(``cluster.restart_cost``, ~10 s, §6).

The cluster itself is a :class:`repro.collectives.cost.ClusterModel`:
capacity, hardware coefficients, an optional node topology (jobs whose
ring spans nodes run on cluster-scaled speed tables) and a GADGET-style
contention penalty (concurrent w>=2 jobs share links and slow each other
down).  A flat homogeneous ClusterModel — the default built from a bare
``capacity`` int — reproduces the paper's setup bit-identically.

With ``ClusterModel(placement=...)`` both engines additionally run the
node-level placement engine (:mod:`repro.core.placement`): every gang
gets a concrete per-node assignment from the placement strategy,
spanning/contention status derives from the *actual* assignment under
fragmentation (each job's speed is its flat table row times its
placement factor, tracked in ``place_factor``/``spanning``), the
migration/defrag pass may consolidate spanning gangs (charging the
restart freeze), and the admission rule may delay (``delayed`` retry
list) or reject arrivals (``SimResult.rejected``).  A placement engine
over a flat cluster is a structural no-op — factors stay exactly 1.0 and
trajectories are bit-identical to the placement-free path (gated by the
60-job golden values and the 1000-job sha256 parity tests).

Two engines, one trajectory:

  * ``engine="table"`` (default) — the hot path, structure-of-arrays.  The
    active set lives in ``_SoAState``: numpy ``remaining`` / ``w`` /
    ``frozen`` / ``speed_now`` arrays plus a 2-D speed-table matrix, all in
    reference active-list order (order is load-bearing for tie-breaks and
    FIFO grants), maintained incrementally — rows append on arrival
    (doubling growth) and compact in place on completion, never rebuilt per
    tick.  Each job's speed curve is sampled once into a table row at
    admission (``JobSpec.speed_table`` is bit-identical to per-scalar
    ``speed`` calls), allocation is one ``policy.allocate`` call over the
    SoA views (:class:`scheduler.AllocView`), the per-event
    completion-estimate scan and progress advance are vectorized slices,
    deterministic events (reschedule ticks, restart-freeze expiries) live
    in a heapq with lazy invalidation, and the next arrival is an index
    into the time-sorted job list.  This is what makes 1000-job traces
    finish in well under a second per strategy.
    Completion estimates are deliberately *recomputed* each event: the
    trajectory ``remaining -= dt * speed`` re-derives the completion time
    from the current (now, remaining) pair at every event, so a cached
    completion event would drift from the reference by one ulp per tick —
    recomputation is what keeps the two engines bit-identical.  Pure
    reschedule ticks skip re-solving only for policies that declare
    ``static = True`` (``fixed_k``, ``utility_greedy``), whose target
    provably depends on nothing but the active-set identity/order; the
    others re-solve every tick because their targets move with
    ``remaining`` (on the Table-3 workloads ~20% of same-active-set
    re-solves change the target, so skipping them would change results).
  * ``engine="reference"`` — the seed O(J)-rescan loop, preserved with the
    seed's cost profile in ``repro.core._reference`` as the parity oracle
    and the "seed" side of benchmarks/bench_scheduler.py.

Both engines share the exploratory-phase gang-grant clamp (a job entering
its explore phase reserves ``min(8, remaining capacity)`` instead of the
old all-or-nothing 8/0 grant, which starved later explorers outright).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.collectives.cost import ClusterModel
from repro.core import _reference, scheduler as sched
from repro.core.jobs import JobSpec
# Shared §6/§7 constants (the explore schedule is policy-owned now);
# re-exported here because callers historically read them off this module.
from repro.core.scheduler import (EXPLORE_SEGMENT, EXPLORE_WS,  # noqa: F401
                                  RESCHEDULE_EVERY)
from repro.core._reference import _Active  # noqa: F401  (compat re-export)

# The restart pause (paper §6, ~10 s) is configured per cluster:
# ``ClusterModel(restart_cost=...)``.  There is deliberately no module
# constant — a module-level knob would silently no-op now that both
# engines read ``cluster.restart_cost``.


@dataclasses.dataclass
class SimResult:
    strategy: str
    completion_times: dict[int, float]
    arrival_times: dict[int, float]
    peak_concurrency: int
    # placement-engine observability (empty/0 on legacy clusters):
    # arrivals the admission rule turned away, and defrag gang moves
    rejected: tuple[int, ...] = ()
    migrations: int = 0

    @property
    def avg_jct_hours(self) -> float:
        jcts = [self.completion_times[j] - self.arrival_times[j]
                for j in self.completion_times]
        return float(np.mean(jcts)) / 3600.0


def _allocate(strategy: str, active: list[_Active], capacity: int,
              now: float) -> dict[int, int]:
    """Target allocation for an ``_Active`` list — a thin adapter over the
    policy registry, kept for tests and ad-hoc callers that hold per-job
    objects instead of SoA state.  Builds the views once and delegates to
    ``policy.allocate``."""
    cluster = ClusterModel(capacity=capacity)
    policy = sched.get_policy(strategy)
    target = policy.allocate(_reference._view_of(active, cluster), cluster,
                             now)
    return {a.spec.job_id: int(w) for a, w in zip(active, target)}


# The table-path adapter collapsed into the same registry call (the
# per-job cached table rows it used to read are superseded by the
# cluster-keyed ``JobSpec.speed_table`` cache the views are built from).
_allocate_table = _allocate


def simulate(jobs: list[JobSpec], capacity: int | None = None,
             strategy: str | sched.SchedulingPolicy = "precompute",
             engine: str = "table",
             cluster: ClusterModel | None = None) -> SimResult:
    """Simulate ``jobs`` on a cluster under a scheduling policy.

    ``strategy`` is a registry spec string (``"precompute"``,
    ``"fixed_8"``, ``"srtf"``, ...) or a policy instance.  Size the
    cluster with either ``capacity`` (a flat homogeneous cluster of that
    many GPUs — the paper's setup; default 64) or ``cluster`` (a full
    :class:`ClusterModel` with topology, contention and restart cost) —
    passing both with disagreeing sizes is an error, not a silent pick.
    """
    if cluster is None:
        cluster = ClusterModel(capacity=64 if capacity is None else capacity)
    elif capacity is not None and capacity != cluster.capacity:
        raise ValueError(
            f"conflicting cluster size: capacity={capacity} but "
            f"cluster.capacity={cluster.capacity}; pass one or make them "
            f"agree")
    policy = sched.get_policy(strategy)
    # stall guard (e.g. a fixed gang larger than the cluster means every
    # job gets the all-or-nothing 0 grant forever and the event loop
    # would tick on reschedules for eternity)
    policy.validate(cluster)
    if engine == "table":
        return _simulate_table(jobs, cluster, policy)
    if engine == "reference":
        return _reference.simulate_reference(jobs, cluster, policy)
    raise ValueError(f"unknown engine {engine!r}")


# Event kinds in the fast engine's static-event heap.
_EV_RESCHED = 0
_EV_UNFREEZE = 1


class _SoAState:
    """Order-preserving structure-of-arrays active set (fast engine).

    One row per active job, in the same order the reference engine keeps
    its ``active`` list (arrival order with in-place removals) — the order
    is load-bearing: solver tie-breaks, FIFO fixed grants and explore-gang
    grants all key off it.  Arrays grow by doubling on arrival and compact
    in place on completion, so per-event work is vectorized slices instead
    of rebuilt per-job tuples.
    """

    __slots__ = ("n", "ids", "remaining", "w", "frozen", "speed_now",
                 "explore_started", "max_w", "place_factor", "spanning",
                 "tables", "index_of")

    def __init__(self, table_width: int, cap: int = 16):
        self.n = 0
        self.ids = np.zeros(cap, np.int64)
        self.remaining = np.zeros(cap)
        self.w = np.zeros(cap, np.int64)
        self.frozen = np.zeros(cap)
        self.speed_now = np.zeros(cap)      # tables[i, w[i]] (0 when w == 0)
        self.explore_started = np.full(cap, -np.inf)
        self.max_w = np.zeros(cap, np.int64)
        # placement-engine rows: speed multiplier over the flat table for
        # the job's current gang assignment, and its actual spanning flag
        # (always 1.0 / False on legacy clusters)
        self.place_factor = np.ones(cap)
        self.spanning = np.zeros(cap, bool)
        self.tables = np.zeros((cap, table_width))
        self.index_of: dict[int, int] = {}

    def _grow(self) -> None:
        cap = 2 * len(self.ids)
        for name in ("ids", "remaining", "w", "frozen", "speed_now",
                     "explore_started", "max_w", "place_factor",
                     "spanning"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[:self.n] = old[:self.n]
            setattr(self, name, new)
        tables = np.zeros((cap, self.tables.shape[1]))
        tables[:self.n] = self.tables[:self.n]
        self.tables = tables

    def add(self, spec: JobSpec, table_row: np.ndarray,
            explore_started: float | None) -> None:
        i = self.n
        if i == len(self.ids):
            self._grow()
        self.ids[i] = spec.job_id
        self.remaining[i] = spec.epochs
        self.w[i] = 0
        self.frozen[i] = 0.0
        self.speed_now[i] = 0.0
        self.explore_started[i] = (-np.inf if explore_started is None
                                   else explore_started)
        self.max_w[i] = spec.max_w
        self.place_factor[i] = 1.0
        self.spanning[i] = False
        self.tables[i, :] = table_row
        self.index_of[spec.job_id] = i
        self.n = i + 1

    def compact(self, keep: np.ndarray) -> None:
        """Drop rows where ``keep`` is False, preserving relative order."""
        n = self.n
        idx = np.nonzero(keep)[0]
        m = len(idx)
        for name in ("ids", "remaining", "w", "frozen", "speed_now",
                     "explore_started", "max_w", "place_factor",
                     "spanning"):
            arr = getattr(self, name)
            arr[:m] = arr[:n][idx]
        self.tables[:m] = self.tables[:n][idx]
        self.n = m
        self.index_of = {int(self.ids[i]): i for i in range(m)}

    def view(self, placement=None) -> sched.AllocView:
        """The policy-facing SoA views over the live rows."""
        n = self.n
        return sched.AllocView(remaining=self.remaining[:n],
                               tables=self.tables,
                               max_w=self.max_w[:n],
                               explore_started=self.explore_started[:n],
                               placement=placement)


def _simulate_table(jobs: list[JobSpec], cluster: ClusterModel,
                    policy: sched.SchedulingPolicy) -> SimResult:
    capacity = cluster.capacity
    restart_cost = cluster.restart_cost
    penalty = cluster.contention_penalty
    peng = None
    if cluster.placement is not None:
        from repro.core.placement import PlacementEngine
        peng = PlacementEngine(cluster)
    pending = sorted(jobs, key=lambda j: j.arrival)
    n_jobs = len(pending)
    pi = 0                        # next-arrival cursor into `pending`
    st = _SoAState(table_width=capacity + 1)
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    delayed: list[JobSpec] = []   # admission-delayed, retried every event
    rejected: list[int] = []
    now = 0.0
    peak = 0
    next_resched = 0.0
    static_key: bytes | None = None
    static_target: np.ndarray | None = None
    # Static-event queue: reschedule ticks and restart-freeze expiries, with
    # lazy invalidation (stale entries are discarded at peek time).
    events: list[tuple[float, int]] = [(0.0, _EV_RESCHED)]

    def apply_alloc(now: float) -> None:
        nonlocal static_key, static_target
        n = st.n
        if policy.static:
            # a static policy's target depends only on the active-set
            # identity/order, so a pure reschedule tick with an unchanged
            # set can reuse the previous solve verbatim
            key = st.ids[:n].tobytes()
            if key != static_key:
                static_key = key
                static_target = policy.allocate(
                    st.view(None if peng is None else peng.view()),
                    cluster, now)
            target = static_target
        else:
            target = policy.allocate(
                st.view(None if peng is None else peng.view()),
                cluster, now)
        changed = np.nonzero(target != st.w[:n])[0]
        if peng is None:
            if not len(changed):
                return
            st.w[:n] = target
            st.speed_now[changed] = st.tables[changed, target[changed]]
            started = changed[target[changed] > 0]
        else:
            # placement pass runs even when no target changed: a
            # completion may have opened a defrag/consolidation move
            st.w[:n] = target
            upd, factors, spans = peng.apply(st.ids[:n], target,
                                             changed.tolist())
            if not len(upd):
                return
            st.place_factor[upd] = factors
            st.spanning[upd] = spans
            st.speed_now[upd] = (st.tables[upd, target[upd]]
                                 * st.place_factor[upd])
            started = upd[target[upd] > 0]
        until = now + restart_cost
        # batched restart freeze: every job whose allocation changed
        # unfreezes at the same instant, so one heap entry covers them all
        # (the per-job push loop was the last Python loop on this path)
        if len(started):
            st.frozen[started] = until
            heapq.heappush(events, (until, _EV_UNFREEZE))

    while pi < n_jobs or st.n or delayed:
        # --- next event time -------------------------------------------
        # discard stale static events, then peek the earliest valid one
        while events:
            t, kind = events[0]
            if kind == _EV_RESCHED:
                if t == next_resched:
                    break
            else:
                # batched unfreeze: valid while any live job still thaws
                # exactly at t (re-freezes move `frozen` past t and
                # completions drop rows — either stales the entry)
                n_ = st.n
                if (t > now and n_
                        and bool(np.any((st.frozen[:n_] == t)
                                        & (st.w[:n_] > 0)))):
                    break
            heapq.heappop(events)
        # a valid reschedule event always exists; an empty queue means the
        # bookkeeping above lost it and the simulation would stall forever
        assert events, "event queue drained: no reschedule event pending"
        t_min = events[0][0]
        if pi < n_jobs and pending[pi].arrival < t_min:
            t_min = pending[pi].arrival
        # completion estimates are recomputed from (now, remaining) every
        # event on purpose — see module docstring (bit-identical trajectory)
        n = st.n
        if n:
            w = st.w[:n]
            frozen = st.frozen[:n]
            speed = st.speed_now[:n]
            if penalty:
                # GADGET-style link sharing: every concurrently-allocated
                # ring job (w >= 2, frozen or not — it holds its links)
                # runs at contention_factor(k) of nominal speed.  Under a
                # placement engine only *actually node-spanning* rings
                # contend — they share the inter-node fabric; intra-node
                # rings never touch it.
                comm = st.spanning[:n] if peng is not None else (w >= 2)
                fac = cluster.contention_factor(int(comm.sum()))
                if fac != 1.0:
                    speed = np.where(comm, speed * fac, speed)
            running = np.nonzero((w > 0) & (frozen <= now)
                                 & (speed > 0.0))[0]
            if len(running):
                est = now + st.remaining[:n][running] / speed[running]
                e_min = est.min()
                if e_min < t_min:
                    t_min = e_min
        t_next = now if t_min < now else t_min

        # --- advance progress -------------------------------------------
        if n:
            dt = t_next - np.maximum(frozen, now)
            adv = np.nonzero((w > 0) & (dt > 0.0))[0]
            if len(adv):
                st.remaining[adv] -= dt[adv] * speed[adv]

        now = t_next

        # --- completions -------------------------------------------------
        finished = False
        if n:
            fin = st.remaining[:n] <= 1e-9
            if fin.any():
                finished = True
                for i in np.nonzero(fin)[0]:
                    done[int(st.ids[i])] = now
                    if peng is not None:
                        peng.release(int(st.ids[i]))
                st.compact(~fin)

        # --- arrivals ----------------------------------------------------
        arrived = False
        if delayed:
            # admission-delayed jobs are retried first at every event
            # (they arrived before anything admitted below)
            still: list[JobSpec] = []
            for j in delayed:
                verdict = peng.admit(j, st.n, len(still), now)
                if verdict == "admit":
                    st.add(j, j.speed_table(cluster),
                           now if policy.explores else None)
                    peng.register(j)
                    arrived = True
                elif verdict == "reject":
                    rejected.append(j.job_id)
                else:
                    still.append(j)
            if still and not arrived and not st.n and pi == n_jobs:
                raise RuntimeError(
                    f"admission rule {cluster.admission!r} stalled: "
                    f"{len(still)} delayed jobs on an idle cluster")
            delayed = still
        while pi < n_jobs and pending[pi].arrival <= now + 1e-9:
            j = pending[pi]
            pi += 1
            if peng is not None:
                verdict = peng.admit(j, st.n, len(delayed), now)
                if verdict == "delay":
                    delayed.append(j)
                    continue
                if verdict == "reject":
                    rejected.append(j.job_id)
                    continue
                peng.register(j)
            # the cluster-keyed table row (flat clusters share the int-path
            # cache, so this is the exact seed table); sized to `capacity`,
            # not j.max_w: j.max_w may exceed the cluster (mixed fleets),
            # and a capacity-sized row makes every _SoAState.tables row the
            # same width — the solver never probes past
            # min(j.max_w, capacity) anyway.
            st.add(j, j.speed_table(cluster),
                   now if policy.explores else None)
            arrived = True

        if st.n > peak:
            peak = st.n

        # --- reallocation ------------------------------------------------
        if arrived or finished or now + 1e-9 >= next_resched:
            if st.n:
                apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY
            heapq.heappush(events, (next_resched, _EV_RESCHED))

    return SimResult(strategy=policy.spec, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak,
                     rejected=tuple(rejected),
                     migrations=0 if peng is None else peng.migrations)


# The paper's Table-3 strategy sweep, plus the registry extensions.
TABLE3_STRATEGIES = ("precompute", "exploratory", "fixed_8", "fixed_4",
                     "fixed_2", "fixed_1", "srtf", "utility_greedy")


def run_table3(seed: int = 0, capacity: int | None = None,
               contention: dict[str, tuple[float, int]] | None = None,
               engine: str = "table",
               pattern: str = "poisson",
               strategies: tuple[str, ...] | None = None,
               cluster: ClusterModel | None = None
               ) -> dict[str, dict[str, float]]:
    """Reproduce Table 3: avg JCT (hours) per strategy x contention level.

    ``pattern`` selects the arrival/size process from the workload-pattern
    library (``jobs.WORKLOAD_PATTERNS``); the paper's own Table 3 is the
    default ``"poisson"`` trace.  ``strategies`` defaults to the paper's
    six plus the registry extensions (srtf, utility_greedy); ``cluster``
    swaps the flat 64-GPU cluster for any :class:`ClusterModel` (e.g. a
    multi-node topology with a contention penalty).
    """
    from repro.core.jobs import make_workload
    contention = contention or {"extreme": (250.0, 206),
                                "moderate": (500.0, 114),
                                "none": (1000.0, 44)}
    strategies = TABLE3_STRATEGIES if strategies is None else strategies
    out: dict[str, dict[str, float]] = {}
    for level, (gap, n_jobs) in contention.items():
        jobs = make_workload(pattern, n_jobs, gap, seed)
        out[level] = {}
        for s in strategies:
            res = simulate(jobs, capacity, s, engine=engine, cluster=cluster)
            out[level][s] = res.avg_jct_hours
    return out
