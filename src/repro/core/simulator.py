"""Cluster scheduler simulation — paper §7.

Event-driven simulation of a C-GPU cluster with Poisson job arrivals.
Strategies are :class:`repro.core.scheduler.SchedulingPolicy` instances
resolved through the policy registry (``scheduler.get_policy``): the
paper's Table-3 set (``precompute``, ``exploratory``, ``fixed_k``) plus
any registered extension (``srtf``, ``utility_greedy``, ...).
Reallocation happens at arrivals, completions and periodic intervals;
every allocation change costs the measured checkpoint-stop-restart pause
(``cluster.restart_cost``, ~10 s, §6).

The cluster itself is a :class:`repro.collectives.cost.ClusterModel`:
capacity, hardware coefficients, an optional node topology (jobs whose
ring spans nodes run on cluster-scaled speed tables) and a GADGET-style
contention penalty (concurrent w>=2 jobs share links and slow each other
down).  A flat homogeneous ClusterModel — the default built from a bare
``capacity`` int — reproduces the paper's setup bit-identically.

With ``ClusterModel(placement=...)`` both engines additionally run the
node-level placement engine (:mod:`repro.core.placement`): every gang
gets a concrete per-node assignment from the placement strategy,
spanning/contention status derives from the *actual* assignment under
fragmentation (each job's speed is its flat table row times its
placement factor, tracked in ``place_factor``/``spanning``), the
migration/defrag pass may consolidate spanning gangs (charging the
restart freeze), and the admission rule may delay (``delayed`` retry
list) or reject arrivals (``SimResult.rejected``).  A placement engine
over a flat cluster is a structural no-op — factors stay exactly 1.0 and
trajectories are bit-identical to the placement-free path (gated by the
60-job golden values and the 1000-job sha256 parity tests).

Two engines, one trajectory:

  * ``engine="table"`` (default) — the hot path, structure-of-arrays with
    cross-tick incremental state.  The active set lives in ``_SoAState``:
    numpy ``remaining`` / ``w`` / ``frozen`` / ``speed_now`` arrays in
    reference active-list order (order is load-bearing for tie-breaks and
    FIFO grants) occupying a sliding window of doubling-growth arrays —
    head completions advance the window in O(1), interior ones shift the
    shorter side.  Speed tables are *interned*: jobs with identical
    speed-determining parameters share one row of a distinct-rows matrix
    through a ``rows`` indirection (``JobSpec.speed_table`` returns
    shared cached arrays, bit-identical to per-scalar ``speed`` calls),
    so a homogeneous 10k-job fleet stores one row, not a 10k-row matrix
    recopied per completion.  Allocation is one ``policy.allocate`` call
    over the SoA views (:class:`scheduler.AllocView`) carrying the
    :class:`scheduler.IncrementalContext` — the admission-seq spine the
    persistent gain-heaps hang solver state off between ticks, so a
    reallocation costs O(changed jobs), not O(active jobs).  Per-event
    scans (completion estimates, progress advance, unfreeze validation,
    contention counts) touch only the dirty slice: the <= capacity rows
    holding workers, tracked incrementally, plus rows admitted since the
    last scan — a saturated 100k-job backlog costs events nothing.
    Deterministic events (reschedule ticks, restart-freeze expiries)
    live in a bucketed calendar queue (``_CalendarQueue``, heap-order
    identical, O(1) amortized for this dense near-future stream), and
    the next arrival is an index into the time-sorted job list.  This is
    what makes 1000-job traces finish in well under a second and
    10k–100k-job traces first-class (seconds to ~a minute per strategy).
    Completion estimates are deliberately *recomputed* each event: the
    trajectory ``remaining -= dt * speed`` re-derives the completion time
    from the current (now, remaining) pair at every event, so a cached
    completion event would drift from the reference by one ulp per tick —
    recomputation is what keeps the two engines bit-identical.  Pure
    reschedule ticks skip re-solving only for policies that declare
    ``static = True`` (``fixed_k``, ``utility_greedy``), whose target
    provably depends on nothing but the active-set identity/order; the
    others re-solve every tick because their targets move with
    ``remaining`` (on the Table-3 workloads ~20% of same-active-set
    re-solves change the target, so skipping them would change results).
  * ``engine="reference"`` — the seed O(J)-rescan loop, preserved with the
    seed's cost profile in ``repro.core._reference`` as the parity oracle
    and the "seed" side of benchmarks/bench_scheduler.py.

Both engines share the exploratory-phase gang-grant clamp (a job entering
its explore phase reserves ``min(8, remaining capacity)`` instead of the
old all-or-nothing 8/0 grant, which starved later explorers outright).
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.collectives.cost import ClusterModel
from repro.core import _reference, scheduler as sched
from repro.core.jobs import JobSpec
# Shared §6/§7 constants (the explore schedule is policy-owned now);
# re-exported here because callers historically read them off this module.
from repro.core.scheduler import (EXPLORE_SEGMENT, EXPLORE_WS,  # noqa: F401
                                  RESCHEDULE_EVERY)
from repro.core._reference import _Active  # noqa: F401  (compat re-export)

# The restart pause (paper §6, ~10 s) is configured per cluster:
# ``ClusterModel(restart_cost=...)``.  There is deliberately no module
# constant — a module-level knob would silently no-op now that both
# engines read ``cluster.restart_cost``.


@dataclasses.dataclass
class SimResult:
    strategy: str
    completion_times: dict[int, float]
    arrival_times: dict[int, float]
    peak_concurrency: int
    # placement-engine observability (empty/0 on legacy clusters):
    # arrivals the admission rule turned away, and defrag gang moves
    rejected: tuple[int, ...] = ()
    migrations: int = 0

    @property
    def avg_jct_hours(self) -> float:
        jcts = [self.completion_times[j] - self.arrival_times[j]
                for j in self.completion_times]
        return float(np.mean(jcts)) / 3600.0


def _allocate(strategy: str, active: list[_Active], capacity: int,
              now: float) -> dict[int, int]:
    """Target allocation for an ``_Active`` list — a thin adapter over the
    policy registry, kept for tests and ad-hoc callers that hold per-job
    objects instead of SoA state.  Builds the views once and delegates to
    ``policy.allocate``."""
    cluster = ClusterModel(capacity=capacity)
    policy = sched.get_policy(strategy)
    target = policy.allocate(_reference._view_of(active, cluster), cluster,
                             now)
    return {a.spec.job_id: int(w) for a, w in zip(active, target)}


# The table-path adapter collapsed into the same registry call (the
# per-job cached table rows it used to read are superseded by the
# cluster-keyed ``JobSpec.speed_table`` cache the views are built from).
_allocate_table = _allocate


def simulate(jobs: list[JobSpec], capacity: int | None = None,
             strategy: str | sched.SchedulingPolicy = "precompute",
             engine: str = "table",
             cluster: ClusterModel | None = None) -> SimResult:
    """Simulate ``jobs`` on a cluster under a scheduling policy.

    ``strategy`` is a registry spec string (``"precompute"``,
    ``"fixed_8"``, ``"srtf"``, ...) or a policy instance.  Size the
    cluster with either ``capacity`` (a flat homogeneous cluster of that
    many GPUs — the paper's setup; default 64) or ``cluster`` (a full
    :class:`ClusterModel` with topology, contention and restart cost) —
    passing both with disagreeing sizes is an error, not a silent pick.
    """
    if cluster is None:
        cluster = ClusterModel(capacity=64 if capacity is None else capacity)
    elif capacity is not None and capacity != cluster.capacity:
        raise ValueError(
            f"conflicting cluster size: capacity={capacity} but "
            f"cluster.capacity={cluster.capacity}; pass one or make them "
            f"agree")
    policy = sched.get_policy(strategy)
    # stall guard (e.g. a fixed gang larger than the cluster means every
    # job gets the all-or-nothing 0 grant forever and the event loop
    # would tick on reschedules for eternity)
    policy.validate(cluster)
    if engine == "table":
        return _simulate_table(jobs, cluster, policy)
    if engine == "reference":
        return _reference.simulate_reference(jobs, cluster, policy)
    raise ValueError(f"unknown engine {engine!r}")


# Event kinds in the fast engine's static-event queue.
_EV_RESCHED = 0
_EV_UNFREEZE = 1


class _CalendarQueue:
    """Bucketed calendar queue for the fast engine's static events.

    Reschedule ticks and restart-unfreeze expiries form a dense,
    near-future, almost-monotone stream: every event lands within
    ``RESCHEDULE_EVERY`` (or ``restart_cost``) of the current time, so a
    calendar of ``width``-second buckets pops in O(1) amortized where a
    binary heap pays O(log pending) and comparison overhead per stale
    entry.  Pop order is identical to ``heapq`` over ``(t, kind)``
    tuples: buckets partition time monotonically and each bucket keeps
    its (few) entries ``bisect``-sorted by the same key, so the head of
    the first non-empty bucket *is* the global lexicographic minimum.
    The cursor only moves forward except when a push lands behind it
    (an unfreeze scheduled while the cursor sits on a far-future
    reschedule tick), which resets it to that bucket.
    """

    __slots__ = ("width", "buckets", "cursor", "n")

    def __init__(self, width: float):
        self.width = width
        self.buckets: dict[int, list[tuple[float, int]]] = {}
        self.cursor = 0
        self.n = 0

    def push(self, t: float, kind: int) -> None:
        b = int(t / self.width)
        lst = self.buckets.get(b)
        if lst is None:
            self.buckets[b] = [(t, kind)]
        else:
            bisect.insort(lst, (t, kind))
        if b < self.cursor or not self.n:
            self.cursor = b
        self.n += 1

    def peek(self) -> tuple[float, int] | None:
        if not self.n:
            return None
        while True:
            lst = self.buckets.get(self.cursor)
            if lst:
                return lst[0]
            self.cursor += 1

    def pop(self) -> tuple[float, int]:
        head = self.peek()
        assert head is not None, "pop from an empty calendar queue"
        lst = self.buckets[self.cursor]
        lst.pop(0)
        if not lst:
            del self.buckets[self.cursor]
        self.n -= 1
        return head


class _SoAState:
    """Order-preserving structure-of-arrays active set (fast engine).

    One row per active job, in the same order the reference engine keeps
    its ``active`` list (arrival order with in-place removals) — the order
    is load-bearing: solver tie-breaks, FIFO fixed grants and explore-gang
    grants all key off it.

    The live rows occupy the window ``[start, start + n)`` of arrays that
    grow by doubling.  A completion removes its row by shifting whichever
    side of the window is *shorter* (head completions — the common case
    under FIFO-ish service — just advance ``start``), so removal costs
    O(min(side)) instead of the full O(n x row-width) matrix copy the
    10k-job traces used to pay per completion.

    Speed tables are *interned*: ``rows[i]`` indexes job i's row in a
    matrix holding only the distinct tables of the fleet (keyed by the
    object identity of the cached ``JobSpec.speed_table`` array), so a
    10k-job homogeneous trace stores one 65-float row, not a 10k x 65
    matrix that must be copied on every completion.

    ``seq`` carries each job's admission number (strictly increasing in
    window order) and ``pos_of_seq`` maps it back to the absolute row
    (-1 once the job is gone) — the spine the cross-tick solver state in
    :mod:`repro.core.scheduler` hangs off.
    """

    _ARRAYS = ("ids", "remaining", "w", "frozen", "speed_now",
               "explore_started", "max_w", "place_factor", "spanning",
               "seq", "rows")

    __slots__ = _ARRAYS + ("n", "start", "tables", "n_rows", "pos_of_seq",
                           "admitted", "_row_ids", "_row_pin", "ctx")

    def __init__(self, table_width: int, cap: int = 16):
        self.n = 0
        self.start = 0
        self.ids = np.zeros(cap, np.int64)
        self.remaining = np.zeros(cap)
        self.w = np.zeros(cap, np.int64)
        self.frozen = np.zeros(cap)
        self.speed_now = np.zeros(cap)      # table[w[i]] (0 when w == 0)
        self.explore_started = np.full(cap, -np.inf)
        self.max_w = np.zeros(cap, np.int64)
        # placement-engine rows: speed multiplier over the flat table for
        # the job's current gang assignment, and its actual spanning flag
        # (always 1.0 / False on legacy clusters)
        self.place_factor = np.ones(cap)
        self.spanning = np.zeros(cap, bool)
        self.seq = np.zeros(cap, np.int64)
        self.rows = np.zeros(cap, np.int64)
        self.tables = np.zeros((4, table_width))
        self.n_rows = 0
        self.pos_of_seq = np.full(cap, -1, np.int64)
        self.admitted = 0
        self._row_ids: dict[int, int] = {}
        self._row_pin: list[np.ndarray] = []    # keeps id() keys alive
        self.ctx = sched.IncrementalContext()

    def _make_room(self) -> None:
        """The window hit the right edge: double the arrays *in place*
        (positions preserved — the engine holds absolute row indices
        across admissions, so the window never slides back; the dead head
        space is bounded by total admissions, a few MB at 100k jobs)."""
        cap = 2 * len(self.ids)
        s, n = self.start, self.n
        for name in self._ARRAYS:
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[s:s + n] = old[s:s + n]
            setattr(self, name, new)

    def _row_id(self, table_row: np.ndarray) -> int:
        """Interned row index for a speed-table array (object identity —
        ``JobSpec.speed_table`` returns shared cached arrays)."""
        rid = self._row_ids.get(id(table_row))
        if rid is None:
            rid = self.n_rows
            if rid == len(self.tables):
                tables = np.zeros((2 * rid, self.tables.shape[1]))
                tables[:rid] = self.tables
                self.tables = tables
            self.tables[rid, :] = table_row
            self._row_ids[id(table_row)] = rid
            self._row_pin.append(table_row)
            self.n_rows = rid + 1
        return rid

    def add(self, spec: JobSpec, table_row: np.ndarray,
            explore_started: float | None) -> int:
        i = self.start + self.n
        if i == len(self.ids):
            self._make_room()
            i = self.start + self.n
        self.ids[i] = spec.job_id
        self.remaining[i] = spec.epochs
        self.w[i] = 0
        self.frozen[i] = 0.0
        self.speed_now[i] = 0.0
        self.explore_started[i] = (-np.inf if explore_started is None
                                   else explore_started)
        self.max_w[i] = spec.max_w
        self.place_factor[i] = 1.0
        self.spanning[i] = False
        self.rows[i] = self._row_id(table_row)
        s = self.admitted
        if s == len(self.pos_of_seq):
            pos = np.full(2 * s, -1, np.int64)
            pos[:s] = self.pos_of_seq
            self.pos_of_seq = pos
        self.seq[i] = s
        self.pos_of_seq[s] = i
        self.admitted = s + 1
        self.n += 1
        return i

    def remove(self, gone: list[int]) -> None:
        """Drop the rows at absolute positions ``gone`` (ascending),
        preserving relative order, by shifting the shorter side."""
        s, n = self.start, self.n
        k = len(gone)
        self.pos_of_seq[self.seq[gone]] = -1
        if gone[-1] - gone[0] == k - 1 and gone[0] == s:
            # contiguous head block: just advance the window
            self.start = s + k
            self.n = n - k
            return
        if k == 1:
            p = gone[0]
            if p - s <= s + n - 1 - p:      # head side shorter: shift right
                for name in self._ARRAYS:
                    arr = getattr(self, name)
                    arr[s + 1:p + 1] = arr[s:p]
                self.pos_of_seq[self.seq[s + 1:p + 1]] += 1
                self.start = s + 1
            else:                           # tail side shorter: shift left
                for name in self._ARRAYS:
                    arr = getattr(self, name)
                    arr[p:s + n - 1] = arr[p + 1:s + n]
                self.pos_of_seq[self.seq[p:s + n - 1]] -= 1
            self.n = n - 1
            return
        keep = np.ones(n, bool)
        keep[np.asarray(gone, np.int64) - s] = False
        kidx = np.nonzero(keep)[0] + s
        m = len(kidx)
        for name in self._ARRAYS:
            arr = getattr(self, name)
            arr[s:s + m] = arr[kidx]
        self.pos_of_seq[self.seq[s:s + m]] = np.arange(s, s + m)
        self.n = m

    def view(self, placement=None) -> sched.AllocView:
        """The policy-facing SoA views over the live window, with the
        refreshed incremental context attached."""
        s, n = self.start, self.n
        ctx = self.ctx
        ctx.pos_of_seq = self.pos_of_seq
        ctx.start = s
        return sched.AllocView(remaining=self.remaining[s:s + n],
                               tables=self.tables[:self.n_rows],
                               max_w=self.max_w[s:s + n],
                               explore_started=self.explore_started[s:s + n],
                               rows=self.rows[s:s + n],
                               seq=self.seq[s:s + n],
                               inc=ctx,
                               placement=placement)


def _simulate_table(jobs: list[JobSpec], cluster: ClusterModel,
                    policy: sched.SchedulingPolicy) -> SimResult:
    capacity = cluster.capacity
    restart_cost = cluster.restart_cost
    penalty = cluster.contention_penalty
    peng = None
    if cluster.placement is not None:
        from repro.core.placement import PlacementEngine
        peng = PlacementEngine(cluster)
    pending = sorted(jobs, key=lambda j: j.arrival)
    n_jobs = len(pending)
    pi = 0                        # next-arrival cursor into `pending`
    st = _SoAState(table_width=capacity + 1)
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    delayed: list[JobSpec] = []   # admission-delayed, retried every event
    rejected: list[int] = []
    now = 0.0
    peak = 0
    next_resched = 0.0
    static_key: tuple | None = None
    static_target: np.ndarray | None = None
    # Static-event queue: reschedule ticks and restart-freeze expiries,
    # bucketed by tick period, with lazy invalidation (stale entries are
    # discarded at peek time).
    events = _CalendarQueue(RESCHEDULE_EVERY)
    events.push(0.0, _EV_RESCHED)
    # Dirty-slice bookkeeping: at most `capacity` jobs hold workers at
    # once, so per-event scans (estimates, advance, unfreeze checks,
    # contention counts) run over `run` — the absolute rows with w > 0 —
    # instead of the thousands of queued w=0 rows a saturated 10k-job
    # trace carries.  `run` (and the cached communicating-job count) only
    # change at allocation changes and completions; `fresh` holds rows
    # admitted since the last completion scan, the only other rows whose
    # remaining work could newly sit at <= 0.
    run = np.empty(0, np.int64)
    comm_n = 0
    fresh: list[int] = []

    def refresh_run() -> None:
        nonlocal run, comm_n
        s, n = st.start, st.n
        w = st.w[s:s + n]
        run = np.nonzero(w > 0)[0] + s
        if penalty:
            comm_n = (int(st.spanning[s:s + n].sum()) if peng is not None
                      else int((w >= 2).sum()))

    def apply_alloc(now: float) -> None:
        nonlocal static_key, static_target
        s, n = st.start, st.n
        if policy.static:
            # a static policy's target depends only on the active-set
            # identity/order, so a pure reschedule tick with an unchanged
            # set can reuse the previous solve verbatim.  The monotone
            # (admissions, completions) counter pair identifies the set:
            # any membership change moves one of them.
            key = (st.admitted, len(done))
            if key != static_key:
                static_key = key
                static_target = policy.allocate(
                    st.view(None if peng is None else peng.view()),
                    cluster, now)
            target = static_target
        else:
            target = policy.allocate(
                st.view(None if peng is None else peng.view()),
                cluster, now)
        changed = np.nonzero(target != st.w[s:s + n])[0]
        if peng is None:
            if not len(changed):
                return
            st.w[s:s + n] = target
            gi = changed + s
            st.speed_now[gi] = st.tables[st.rows[gi], target[changed]]
            started = gi[target[changed] > 0]
        else:
            # placement pass runs even when no target changed: a
            # completion may have opened a defrag/consolidation move
            st.w[s:s + n] = target
            upd, factors, spans = peng.apply(st.ids[s:s + n], target,
                                             changed.tolist())
            if not len(upd):
                return
            gi = upd + s
            st.place_factor[gi] = factors
            st.spanning[gi] = spans
            st.speed_now[gi] = (st.tables[st.rows[gi], target[upd]]
                                * factors)
            started = gi[target[upd] > 0]
        refresh_run()
        until = now + restart_cost
        # batched restart freeze: every job whose allocation changed
        # unfreezes at the same instant, so one queue entry covers them
        # all (the per-job push loop was the last Python loop here)
        if len(started):
            st.frozen[started] = until
            events.push(until, _EV_UNFREEZE)

    while pi < n_jobs or st.n or delayed:
        # --- next event time -------------------------------------------
        # discard stale static events, then peek the earliest valid one
        while True:
            head = events.peek()
            # a valid reschedule event always exists; an empty queue means
            # the bookkeeping lost it and the loop would stall forever
            assert head is not None, (
                "event queue drained: no reschedule event pending")
            t, kind = head
            if kind == _EV_RESCHED:
                if t == next_resched:
                    break
            else:
                # batched unfreeze: valid while any live allocated job
                # still thaws exactly at t (re-freezes move `frozen` past
                # t and completions drop rows — either stales the entry)
                if (t > now and len(run)
                        and bool(np.any(st.frozen[run] == t))):
                    break
            events.pop()
        t_min = t
        if pi < n_jobs and pending[pi].arrival < t_min:
            t_min = pending[pi].arrival
        # completion estimates are recomputed from (now, remaining) every
        # event on purpose — see module docstring (bit-identical
        # trajectory); only the w>0 slice can run, so only it is scanned
        frozen_r = speed_r = None
        if len(run):
            frozen_r = st.frozen[run]
            speed_r = st.speed_now[run]
            if penalty:
                # GADGET-style link sharing: every concurrently-allocated
                # ring job (w >= 2, frozen or not — it holds its links)
                # runs at contention_factor(k) of nominal speed.  Under a
                # placement engine only *actually node-spanning* rings
                # contend — they share the inter-node fabric; intra-node
                # rings never touch it.  (The count is cached: it only
                # moves when allocations or membership do.)
                fac = cluster.contention_factor(comm_n)
                if fac != 1.0:
                    comm = (st.spanning[run] if peng is not None
                            else st.w[run] >= 2)
                    speed_r = np.where(comm, speed_r * fac, speed_r)
            sel = (frozen_r <= now) & (speed_r > 0.0)
            if sel.any():
                est = now + st.remaining[run[sel]] / speed_r[sel]
                e_min = est.min()
                if e_min < t_min:
                    t_min = e_min
        t_next = now if t_min < now else t_min

        # --- advance progress -------------------------------------------
        adv = None
        if len(run):
            dt = t_next - np.maximum(frozen_r, now)
            pos = dt > 0.0
            if pos.any():
                adv = run[pos]
                st.remaining[adv] -= dt[pos] * speed_r[pos]

        now = t_next

        # --- completions -------------------------------------------------
        # only rows that advanced (or were just admitted) can newly reach
        # the threshold — the dirty slice of the old full-width scan
        finished = False
        if fresh:
            cand = (np.asarray(fresh, np.int64) if adv is None
                    else np.concatenate((adv, np.asarray(fresh, np.int64))))
            fresh = []
        else:
            cand = adv
        if cand is not None and len(cand):
            fin = st.remaining[cand] <= 1e-9
            if fin.any():
                finished = True
                gone = np.unique(cand[fin])        # ascending, like the
                for i in gone.tolist():            # old full-width scan
                    done[int(st.ids[i])] = now
                    if peng is not None:
                        peng.release(int(st.ids[i]))
                st.remove(gone.tolist())
                refresh_run()

        # --- arrivals ----------------------------------------------------
        arrived = False
        if delayed:
            # admission-delayed jobs are retried first at every event
            # (they arrived before anything admitted below)
            still: list[JobSpec] = []
            for j in delayed:
                verdict = peng.admit(j, st.n, len(still), now)
                if verdict == "admit":
                    fresh.append(st.add(j, j.speed_table(cluster),
                                        now if policy.explores else None))
                    peng.register(j)
                    arrived = True
                elif verdict == "reject":
                    rejected.append(j.job_id)
                else:
                    still.append(j)
            if still and not arrived and not st.n and pi == n_jobs:
                raise RuntimeError(
                    f"admission rule {cluster.admission!r} stalled: "
                    f"{len(still)} delayed jobs on an idle cluster")
            delayed = still
        while pi < n_jobs and pending[pi].arrival <= now + 1e-9:
            j = pending[pi]
            pi += 1
            if peng is not None:
                verdict = peng.admit(j, st.n, len(delayed), now)
                if verdict == "delay":
                    delayed.append(j)
                    continue
                if verdict == "reject":
                    rejected.append(j.job_id)
                    continue
                peng.register(j)
            # the cluster-keyed table row (flat clusters share the int-path
            # cache, so this is the exact seed table); sized to `capacity`,
            # not j.max_w: j.max_w may exceed the cluster (mixed fleets),
            # and a capacity-sized row makes every interned table row the
            # same width — the solver never probes past
            # min(j.max_w, capacity) anyway.
            fresh.append(st.add(j, j.speed_table(cluster),
                                now if policy.explores else None))
            arrived = True

        if st.n > peak:
            peak = st.n

        # --- reallocation ------------------------------------------------
        if arrived or finished or now + 1e-9 >= next_resched:
            if st.n:
                apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY
            events.push(next_resched, _EV_RESCHED)

    return SimResult(strategy=policy.spec, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak,
                     rejected=tuple(rejected),
                     migrations=0 if peng is None else peng.migrations)


# The paper's Table-3 strategy sweep, plus the registry extensions.
TABLE3_STRATEGIES = ("precompute", "exploratory", "fixed_8", "fixed_4",
                     "fixed_2", "fixed_1", "srtf", "utility_greedy")


def run_table3(seed: int = 0, capacity: int | None = None,
               contention: dict[str, tuple[float, int]] | None = None,
               engine: str = "table",
               pattern: str = "poisson",
               strategies: tuple[str, ...] | None = None,
               cluster: ClusterModel | None = None
               ) -> dict[str, dict[str, float]]:
    """Reproduce Table 3: avg JCT (hours) per strategy x contention level.

    ``pattern`` selects the arrival/size process from the workload-pattern
    library (``jobs.WORKLOAD_PATTERNS``); the paper's own Table 3 is the
    default ``"poisson"`` trace.  ``strategies`` defaults to the paper's
    six plus the registry extensions (srtf, utility_greedy); ``cluster``
    swaps the flat 64-GPU cluster for any :class:`ClusterModel` (e.g. a
    multi-node topology with a contention penalty).
    """
    from repro.core.jobs import make_workload
    contention = contention or {"extreme": (250.0, 206),
                                "moderate": (500.0, 114),
                                "none": (1000.0, 44)}
    strategies = TABLE3_STRATEGIES if strategies is None else strategies
    out: dict[str, dict[str, float]] = {}
    for level, (gap, n_jobs) in contention.items():
        jobs = make_workload(pattern, n_jobs, gap, seed)
        out[level] = {}
        for s in strategies:
            res = simulate(jobs, capacity, s, engine=engine, cluster=cluster)
            out[level][s] = res.avg_jct_hours
    return out
