"""Cluster scheduler simulation — paper §7.

Event-driven simulation of a C-GPU cluster with Poisson job arrivals.
Strategies (Table 3): ``precompute``, ``exploratory``, and fixed 1/2/4/8.
Reallocation happens at arrivals, completions and periodic intervals; every
allocation change costs the measured checkpoint-stop-restart pause (~10 s,
§6).  The exploratory strategy gives a new job 8 GPUs for its first ten
minutes, running 2.5 min at each of 1, 2, 4, 8 GPUs to collect the (w, f(w))
points the resource model (eq. 5) needs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from repro.core import scheduler as sched
from repro.core.jobs import JobSpec

RESTART_COST = 10.0          # seconds (paper §6)
EXPLORE_SEGMENT = 150.0      # 2.5 minutes at each of 1, 2, 4, 8 (§7)
EXPLORE_WS = (1, 2, 4, 8)
RESCHEDULE_EVERY = 150.0


@dataclasses.dataclass
class _Active:
    spec: JobSpec
    remaining: float              # epochs
    w: int = 0
    frozen_until: float = 0.0     # restart pause
    explore_started: float | None = None

    def explore_w(self, now: float) -> int | None:
        """Worker count dictated by the explore phase, or None if done."""
        if self.explore_started is None:
            return None
        seg = int((now - self.explore_started) // EXPLORE_SEGMENT)
        if seg >= len(EXPLORE_WS):
            return None
        return EXPLORE_WS[seg]

    def speed(self, now: float) -> float:
        if now < self.frozen_until or self.w <= 0:
            return 0.0
        return self.spec.speed(self.w)


@dataclasses.dataclass
class SimResult:
    strategy: str
    completion_times: dict[int, float]
    arrival_times: dict[int, float]
    peak_concurrency: int

    @property
    def avg_jct_hours(self) -> float:
        jcts = [self.completion_times[j] - self.arrival_times[j]
                for j in self.completion_times]
        return float(np.mean(jcts)) / 3600.0


def _allocate(strategy: str, active: list[_Active], capacity: int,
              now: float) -> dict[int, int]:
    """Target allocation for the current set of active jobs."""
    if strategy.startswith("fixed"):
        k = int(strategy.split("_")[1])
        tuples = [(a.spec.job_id, a.remaining, a.spec.speed) for a in active]
        return sched.fixed(tuples, capacity, k)

    alloc: dict[int, int] = {}
    cap = capacity
    dynamic: list[_Active] = []
    if strategy == "exploratory":
        # explore-phase jobs hold 8 GPUs (gang) while profiling
        for a in active:
            ew = a.explore_w(now)
            if ew is not None:
                grant = 8 if cap >= 8 else 0
                alloc[a.spec.job_id] = min(ew, grant) if grant else 0
                cap -= grant
            else:
                dynamic.append(a)
    else:  # precompute: all jobs schedulable immediately
        dynamic = list(active)
    tuples = [(a.spec.job_id, a.remaining, a.spec.speed) for a in dynamic]
    alloc.update(sched.doubling_heuristic(tuples, max(cap, 0),
                                          max_w=active[0].spec.max_w
                                          if active else 8))
    return alloc


def simulate(jobs: list[JobSpec], capacity: int = 64,
             strategy: str = "precompute") -> SimResult:
    pending = sorted(jobs, key=lambda j: j.arrival)
    active: list[_Active] = []
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    now = 0.0
    peak = 0
    next_resched = 0.0

    def apply_alloc(now: float):
        target = _allocate(strategy, active, capacity, now)
        for a in active:
            w_new = target.get(a.spec.job_id, 0)
            if w_new != a.w:
                a.w = w_new
                if w_new > 0:
                    a.frozen_until = now + RESTART_COST
        # also freeze explore-phase jobs at segment switches implicitly via
        # reschedule events (RESCHEDULE_EVERY == EXPLORE_SEGMENT).

    while pending or active:
        # --- next event time -------------------------------------------
        t_candidates = []
        if pending:
            t_candidates.append(pending[0].arrival)
        t_candidates.append(next_resched)
        for a in active:
            s = a.speed(now)
            if s > 0:
                t_candidates.append(max(now, a.frozen_until)
                                    + a.remaining / s)
            elif a.w > 0 and a.frozen_until > now:
                t_candidates.append(a.frozen_until)
        if not t_candidates:
            t_candidates = [pending[0].arrival]
        t_next = max(now, min(t_candidates))

        # --- advance progress -------------------------------------------
        for a in active:
            run_from = max(now, a.frozen_until)
            dt = max(0.0, t_next - run_from)
            a.remaining -= dt * (a.spec.speed(a.w) if a.w > 0 else 0.0)

        now = t_next

        # --- completions -------------------------------------------------
        finished = [a for a in active if a.remaining <= 1e-9]
        for a in finished:
            done[a.spec.job_id] = now
            active.remove(a)

        # --- arrivals ----------------------------------------------------
        arrived = False
        while pending and pending[0].arrival <= now + 1e-9:
            j = pending.pop(0)
            a = _Active(spec=j, remaining=j.epochs)
            if strategy == "exploratory":
                a.explore_started = now
            active.append(a)
            arrived = True

        peak = max(peak, len(active))

        # --- reallocation ------------------------------------------------
        if arrived or finished or now + 1e-9 >= next_resched:
            if active:
                apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY

    return SimResult(strategy=strategy, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak)


def run_table3(seed: int = 0, capacity: int = 64,
               contention: dict[str, tuple[float, int]] | None = None
               ) -> dict[str, dict[str, float]]:
    """Reproduce Table 3: avg JCT (hours) per strategy x contention level."""
    from repro.core.jobs import synthetic_workload
    contention = contention or {"extreme": (250.0, 206),
                                "moderate": (500.0, 114),
                                "none": (1000.0, 44)}
    strategies = ["precompute", "exploratory", "fixed_8", "fixed_4",
                  "fixed_2", "fixed_1"]
    out: dict[str, dict[str, float]] = {}
    for level, (gap, n_jobs) in contention.items():
        jobs = synthetic_workload(n_jobs, gap, seed)
        out[level] = {}
        for s in strategies:
            res = simulate(jobs, capacity, s)
            out[level][s] = res.avg_jct_hours
    return out
