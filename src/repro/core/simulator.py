"""Cluster scheduler simulation — paper §7.

Event-driven simulation of a C-GPU cluster with Poisson job arrivals.
Strategies are :class:`repro.core.scheduler.SchedulingPolicy` instances
resolved through the policy registry (``scheduler.get_policy``): the
paper's Table-3 set (``precompute``, ``exploratory``, ``fixed_k``) plus
any registered extension (``srtf``, ``utility_greedy``, ...).
Reallocation happens at arrivals, completions and periodic intervals;
every allocation change costs the measured checkpoint-stop-restart pause
(``cluster.restart_cost``, ~10 s, §6).

The cluster itself is a :class:`repro.collectives.cost.ClusterModel`:
capacity, hardware coefficients, an optional node topology (jobs whose
ring spans nodes run on cluster-scaled speed tables) and a GADGET-style
contention penalty (concurrent w>=2 jobs share links and slow each other
down).  A flat homogeneous ClusterModel — the default built from a bare
``capacity`` int — reproduces the paper's setup bit-identically.

With ``ClusterModel(placement=...)`` both engines additionally run the
node-level placement engine (:mod:`repro.core.placement`): every gang
gets a concrete per-node assignment from the placement strategy,
spanning/contention status derives from the *actual* assignment under
fragmentation (each job's speed is its flat table row times its
placement factor, tracked in ``place_factor``/``spanning``), the
migration/defrag pass may consolidate spanning gangs (charging the
restart freeze), and the admission rule may delay (``delayed`` retry
list) or reject arrivals (``SimResult.rejected``).  A placement engine
over a flat cluster is a structural no-op — factors stay exactly 1.0 and
trajectories are bit-identical to the placement-free path (gated by the
60-job golden values and the 1000-job sha256 parity tests).

Two engines, one trajectory:

  * ``engine="table"`` (default) — the hot path, structure-of-arrays with
    cross-tick incremental state.  The active set lives in ``_SoAState``:
    numpy ``remaining`` / ``w`` / ``frozen`` / ``speed_now`` arrays in
    reference active-list order (order is load-bearing for tie-breaks and
    FIFO grants) occupying a sliding window of doubling-growth arrays —
    head completions advance the window in O(1), interior ones shift the
    shorter side (never the whole set — removal is O(min side)).  Speed tables are *interned*: jobs with identical
    speed-determining parameters share one row of a distinct-rows matrix
    through a ``rows`` indirection (``JobSpec.speed_table`` returns
    shared cached arrays, bit-identical to per-scalar ``speed`` calls),
    so a homogeneous 10k-job fleet stores one row, not a 10k-row matrix
    recopied per completion.  Allocation is one ``policy.allocate`` call
    over the SoA views (:class:`scheduler.AllocView`) carrying the
    :class:`scheduler.IncrementalContext` — the admission-seq spine the
    persistent gain-heaps hang solver state off between ticks, so a
    reallocation costs O(changed jobs), not O(active jobs).  Per-event
    scans (completion estimates, progress advance, unfreeze validation,
    contention counts) touch only the dirty slice: the <= capacity rows
    holding workers, tracked incrementally, plus rows admitted since the
    last scan — a saturated 100k-job backlog costs events nothing.
    ``slotted`` policies return a sparse :class:`scheduler.AllocDelta`
    (only the rows whose allocation may have moved) that the engine
    applies in O(Δ) — no dense target, no full-width compare — and when
    the running set is small (<= 16 rows, srtf's steady state) the
    per-event scans run as plain-float scalar loops over a cached
    effective-speed list: the same IEEE-754 elementwise operations the
    vectorized path performs, so the trajectory stays bit-identical.
    Deterministic events (reschedule ticks, restart-freeze expiries)
    live in a bucketed calendar queue (``_CalendarQueue``, heap-order
    identical, O(1) amortized for this dense near-future stream), and
    the next arrival is an index into the time-sorted job list.  This is
    what makes 1000-job traces finish in well under a second and
    10k–100k-job traces first-class (seconds to ~a minute per strategy).
    Completion estimates are deliberately *recomputed* each event: the
    trajectory ``remaining -= dt * speed`` re-derives the completion time
    from the current (now, remaining) pair at every event, so a cached
    completion event would drift from the reference by one ulp per tick —
    recomputation is what keeps the two engines bit-identical.  Pure
    reschedule ticks skip re-solving only for policies that declare
    ``static = True`` (``fixed_k``, ``utility_greedy``), whose target
    provably depends on nothing but the active-set identity/order; the
    others re-solve every tick because their targets move with
    ``remaining`` (on the Table-3 workloads ~20% of same-active-set
    re-solves change the target, so skipping them would change results).
  * ``engine="reference"`` — the seed O(J)-rescan loop, preserved with the
    seed's cost profile in ``repro.core._reference`` as the parity oracle
    and the "seed" side of benchmarks/bench_scheduler.py.

Both engines share the exploratory-phase gang-grant clamp (a job entering
its explore phase reserves ``min(8, remaining capacity)`` instead of the
old all-or-nothing 8/0 grant, which starved later explorers outright).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from time import perf_counter

import numpy as np

from repro.collectives.cost import ClusterModel
from repro.core import _reference, scheduler as sched
from repro.core import telemetry as _tele
from repro.core.jobs import JobSpec
# Shared §6/§7 constants (the explore schedule is policy-owned now);
# re-exported here because callers historically read them off this module.
from repro.core.scheduler import (EXPLORE_SEGMENT, EXPLORE_WS,  # noqa: F401
                                  RESCHEDULE_EVERY)
from repro.core._reference import _Active  # noqa: F401  (compat re-export)

# The restart pause (paper §6, ~10 s) is configured per cluster:
# ``ClusterModel(restart_cost=...)``.  There is deliberately no module
# constant — a module-level knob would silently no-op now that both
# engines read ``cluster.restart_cost``.


@dataclasses.dataclass
class SimResult:
    strategy: str
    completion_times: dict[int, float]
    arrival_times: dict[int, float]
    peak_concurrency: int
    # placement-engine observability (empty/0 on legacy clusters):
    # arrivals the admission rule turned away, and defrag gang moves
    rejected: tuple[int, ...] = ()
    migrations: int = 0
    # fault injection (PR 10): gangs killed by node failures (0 on
    # fault-free runs; compared by the engine-parity gates)
    evictions: int = 0
    # end-of-run metrics rollup (``telemetry.TelemetryResult``) when the
    # run was telemetered (``simulate(..., telemetry=...)``), else None
    telemetry: object | None = None

    @property
    def avg_jct_hours(self) -> float:
        jcts = [self.completion_times[j] - self.arrival_times[j]
                for j in self.completion_times]
        return float(np.mean(jcts)) / 3600.0

    @property
    def utilization(self) -> float | None:
        """Time-weighted mean busy-GPU fraction over the run.

        Allocated GPUs count as busy — a frozen (restarting) gang still
        holds its GPUs.  Computed from the telemetry event integrals, so
        it is ``None`` unless the run was telemetered; both engines
        produce bitwise-equal values (asserted by the parity gates).
        """
        t = self.telemetry
        return None if t is None else t.utilization


def _allocate(strategy: str, active: list[_Active], capacity: int,
              now: float) -> dict[int, int]:
    """Target allocation for an ``_Active`` list — a thin adapter over the
    policy registry, kept for tests and ad-hoc callers that hold per-job
    objects instead of SoA state.  Builds the views once and delegates to
    ``policy.allocate``."""
    cluster = ClusterModel(capacity=capacity)
    policy = sched.get_policy(strategy)
    target = policy.allocate(_reference._view_of(active, cluster), cluster,
                             now)
    return {a.spec.job_id: int(w) for a, w in zip(active, target)}


# The table-path adapter collapsed into the same registry call (the
# per-job cached table rows it used to read are superseded by the
# cluster-keyed ``JobSpec.speed_table`` cache the views are built from).
_allocate_table = _allocate


def simulate(jobs: list[JobSpec], capacity: int | None = None,
             strategy: str | sched.SchedulingPolicy = "precompute",
             engine: str = "table",
             cluster: ClusterModel | None = None,
             telemetry: object | None = None) -> SimResult:
    """Simulate ``jobs`` on a cluster under a scheduling policy.

    ``strategy`` is a registry spec string (``"precompute"``,
    ``"fixed_8"``, ``"srtf"``, ...) or a policy instance.  Size the
    cluster with either ``capacity`` (a flat homogeneous cluster of that
    many GPUs — the paper's setup; default 64) or ``cluster`` (a full
    :class:`ClusterModel` with topology, contention and restart cost) —
    passing both with disagreeing sizes is an error, not a silent pick.

    ``telemetry`` is a :class:`repro.core.telemetry.Telemetry` handle to
    record the run (events, counters, utilization — attached to
    ``SimResult.telemetry``); ``None`` (the default) runs the
    zero-overhead disabled path and leaves ``SimResult.telemetry`` None.
    The trajectory is bit-identical either way (gated by the parity
    suite).
    """
    if cluster is None:
        cluster = ClusterModel(capacity=64 if capacity is None else capacity)
    elif capacity is not None and capacity != cluster.capacity:
        raise ValueError(
            f"conflicting cluster size: capacity={capacity} but "
            f"cluster.capacity={cluster.capacity}; pass one or make them "
            f"agree")
    policy = sched.get_policy(strategy)
    # stall guard (e.g. a fixed gang larger than the cluster means every
    # job gets the all-or-nothing 0 grant forever and the event loop
    # would tick on reschedules for eternity)
    policy.validate(cluster)
    tel = _tele.NULL if telemetry is None else telemetry
    if engine == "table":
        return _simulate_table(jobs, cluster, policy, tel)
    if engine == "reference":
        return _reference.simulate_reference(jobs, cluster, policy, tel)
    raise ValueError(f"unknown engine {engine!r}")


# Event kinds in the fast engine's static-event queue.
_EV_RESCHED = 0
_EV_UNFREEZE = 1

# Shared "no completions this event" sentinel: the scalar advance loop
# compares by identity and only allocates a real list on the first find,
# so the common no-completion event allocates nothing.  Never mutated.
_NO_COMP: list = []


class _CalendarQueue:
    """Bucketed calendar queue for the fast engine's static events.

    Reschedule ticks and restart-unfreeze expiries form a dense,
    near-future, almost-monotone stream: every event lands within
    ``RESCHEDULE_EVERY`` (or ``restart_cost``) of the current time, so a
    calendar of ``width``-second buckets pops in O(1) amortized where a
    binary heap pays O(log pending) and comparison overhead per stale
    entry.  Pop order is identical to ``heapq`` over ``(t, kind)``
    tuples: buckets partition time monotonically and each bucket keeps
    its (few) entries ``bisect``-sorted by the same key, so the head of
    the first non-empty bucket *is* the global lexicographic minimum.
    The cursor only moves forward except when a push lands behind it
    (an unfreeze scheduled while the cursor sits on a far-future
    reschedule tick), which resets it to that bucket.
    """

    __slots__ = ("width", "buckets", "cursor", "n")

    def __init__(self, width: float):
        self.width = width
        self.buckets: dict[int, list[tuple[float, int]]] = {}
        self.cursor = 0
        self.n = 0

    def push(self, t: float, kind: int) -> None:
        b = int(t / self.width)
        lst = self.buckets.get(b)
        if lst is None:
            self.buckets[b] = [(t, kind)]
        else:
            bisect.insort(lst, (t, kind))
        if b < self.cursor or not self.n:
            self.cursor = b
        self.n += 1

    def peek(self) -> tuple[float, int] | None:
        if not self.n:
            return None
        while True:
            lst = self.buckets.get(self.cursor)
            if lst:
                return lst[0]
            self.cursor += 1

    def pop(self) -> tuple[float, int]:
        head = self.peek()
        assert head is not None, "pop from an empty calendar queue"
        lst = self.buckets[self.cursor]
        lst.pop(0)
        if not lst:
            del self.buckets[self.cursor]
        self.n -= 1
        return head


class _SoAState:
    """Slot-stable structure-of-arrays active set (fast engine).

    One row per *admitted* job, indexed by its admission slot: row
    ``s`` is the s-th job ever admitted, and rows never move.  Slot
    order is arrival order — the order the reference engine's active
    list preserves and every solver tie-break keys off — so the live
    subsequence of the slot space *is* the reference list.  A completion
    flips ``alive[s]`` off in O(1) (plus amortized-O(1) bookkeeping
    below) instead of shifting array rows: the min-side memmove the old
    windowed layout paid per interior completion was SRTF's worst case
    (its completions land mid-window by design) and priced 1M-job
    traces out entirely.

    Dead slots are skipped on enumeration through ``nxt``, a
    path-compressed next-live pointer chain (``nxt[s]`` = first
    possibly-live slot after a dead ``s``), giving O(α) amortized hops;
    ``lo``/``hi`` bound the live region and ``n`` counts it.

    ``pref`` caches the FIFO candidate prefix — the first
    ``min(n, capacity)`` live slots, the only jobs any seeded solver
    can grant workers — maintained incrementally (append on arrival,
    bisect-patch + next-live refill on a prefix death), so a solver's
    ``prefix(k)`` call is an O(1) ndarray slice instead of an O(n) live
    scan.

    Speed tables are *interned*: ``rows[s]`` indexes job s's row in a
    matrix holding only the distinct tables of the fleet (keyed by the
    object identity of the cached ``JobSpec.speed_table`` array), so a
    10k-job homogeneous trace stores one 65-float row, not a 10k x 65
    matrix.
    """

    _ARRAYS = ("ids", "remaining", "w", "frozen", "speed_now",
               "explore_started", "max_w", "place_factor", "spanning",
               "rows")

    __slots__ = _ARRAYS + ("n", "lo", "hi", "alive", "nxt", "tables",
                           "n_rows", "tables_pos", "pref", "pref_cap",
                           "pref_version", "_pref_arr", "_pref_dirty",
                           "_row_ids", "_row_pin", "ctx", "_view")

    def __init__(self, table_width: int, cap: int = 16,
                 prefix_cap: int | None = None):
        self.n = 0                          # live jobs
        self.lo = 0                         # first possibly-live slot
        self.hi = 0                         # one past the last admitted
        self.ids = np.zeros(cap, np.int64)
        self.remaining = np.zeros(cap)
        self.w = np.zeros(cap, np.int64)
        self.frozen = np.zeros(cap)
        self.speed_now = np.zeros(cap)      # table[w[i]] (0 when w == 0)
        self.explore_started = np.full(cap, -np.inf)
        self.max_w = np.zeros(cap, np.int64)
        # placement-engine rows: speed multiplier over the flat table for
        # the job's current gang assignment, and its actual spanning flag
        # (always 1.0 / False on legacy clusters)
        self.place_factor = np.ones(cap)
        self.spanning = np.zeros(cap, bool)
        self.rows = np.zeros(cap, np.int64)
        self.alive = np.zeros(cap, bool)
        self.nxt = np.zeros(cap, np.int64)
        self.tables = np.zeros((4, table_width))
        self.n_rows = 0
        # every interned row has f(w) > 0 for all w >= 1 (checked once
        # per distinct row) — lets the engine skip per-event speed masks
        self.tables_pos = True
        # FIFO prefix cache: first min(n, pref_cap) live slots (the
        # engine builds tables capacity+1 wide, so that is the default)
        self.pref: list[int] = []
        self.pref_cap = (max(table_width - 1, 1) if prefix_cap is None
                         else prefix_cap)
        self.pref_version = 0   # bumped on any prefix membership change
        self._pref_arr = np.empty(0, np.int64)
        self._pref_dirty = False
        self._row_ids: dict[int, int] = {}
        self._row_pin: list[np.ndarray] = []    # keeps id() keys alive
        self.ctx = sched.IncrementalContext()
        self._view: sched.AllocView | None = None

    def _make_room(self) -> None:
        """Slot space full: double every array (slots are absolute and
        never move, so this is one copy of the admitted region —
        amortized O(1) per admission)."""
        cap = 2 * len(self.ids)
        hi = self.hi
        for name in self._ARRAYS + ("alive", "nxt"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[:hi] = old[:hi]
            setattr(self, name, new)

    def _row_id(self, table_row: np.ndarray) -> int:
        """Interned row index for a speed-table array (object identity —
        ``JobSpec.speed_table`` returns shared cached arrays)."""
        rid = self._row_ids.get(id(table_row))
        if rid is None:
            rid = self.n_rows
            if rid == len(self.tables):
                tables = np.zeros((2 * rid, self.tables.shape[1]))
                tables[:rid] = self.tables
                self.tables = tables
            self.tables[rid, :] = table_row
            self._row_ids[id(table_row)] = rid
            self._row_pin.append(table_row)
            self.n_rows = rid + 1
            if not bool((table_row[1:] > 0.0).all()):
                self.tables_pos = False
        return rid

    def add(self, spec: JobSpec, table_row: np.ndarray,
            explore_started: float | None) -> int:
        i = self.hi
        if i == len(self.ids):
            self._make_room()
        self.ids[i] = spec.job_id
        self.remaining[i] = spec.epochs
        self.w[i] = 0
        self.frozen[i] = 0.0
        self.speed_now[i] = 0.0
        self.explore_started[i] = (-np.inf if explore_started is None
                                   else explore_started)
        self.max_w[i] = spec.max_w
        self.place_factor[i] = 1.0
        self.spanning[i] = False
        self.rows[i] = self._row_id(table_row)
        self.alive[i] = True
        self.nxt[i] = i + 1     # read only once dead: the successor slot
        self.hi = i + 1
        self.n += 1
        if len(self.pref) < self.pref_cap:
            self.pref.append(i)
            self.pref_version += 1
            self._pref_dirty = True
        return i

    def _find(self, s: int) -> int:
        """First live slot >= ``s`` (caller guarantees one exists), with
        path compression over the dead slots walked."""
        alive, nxt = self.alive, self.nxt
        r = s
        while not alive[r]:
            r = int(nxt[r])
        if r > s:
            while not alive[s]:
                t = int(nxt[s])
                nxt[s] = r
                s = t
        return r

    def remove(self, gone: list[int]) -> None:
        """Mark the jobs at slots ``gone`` (ascending) dead: O(1) per
        slot plus amortized-O(1) ``lo`` advance and O(prefix-deaths)
        prefix patching — never an array shift."""
        alive = self.alive
        for s in gone:
            alive[s] = False
        self.n -= len(gone)
        lo, hi = self.lo, self.hi
        while lo < hi and not alive[lo]:
            lo += 1
        self.lo = lo
        pref = self.pref
        if pref and gone[0] <= pref[-1]:
            for s in gone:
                if not pref or s > pref[-1]:
                    break
                j = bisect.bisect_left(pref, s)
                if j < len(pref) and pref[j] == s:
                    del pref[j]
            # refill from the next live slots beyond the prefix so the
            # invariant len(pref) == min(n, pref_cap) holds
            while len(pref) < self.n and len(pref) < self.pref_cap:
                pref.append(self._find(pref[-1] + 1 if pref else lo))
            self.pref_version += 1
            self._pref_dirty = True

    def _prefix(self, k: int) -> np.ndarray:
        """Slots of the first ``k`` live jobs (k <= min(n, pref_cap))."""
        if self._pref_dirty:
            self._pref_arr = np.array(self.pref, np.int64)
            self._pref_dirty = False
        return self._pref_arr[:k]

    def live_slots(self) -> np.ndarray:
        """All live slots, ascending — the dense active-set order.  O(hi
        - lo): only the placement path and non-slotted (dense-contract)
        policies pay it."""
        return np.nonzero(self.alive[self.lo:self.hi])[0] + self.lo

    def view(self, placement=None) -> sched.AllocView:
        """The slotted policy-facing view: full slot-indexed arrays plus
        the refreshed incremental context.  The view object is reused
        across solves — only the scalars move between them; the array
        fields are rebound when the backing arrays grow (``_make_room``
        reassigns all of them together, so one identity check covers
        the lot) or a new table row is interned."""
        ctx = self.ctx
        ctx.pref_version = self.pref_version
        v = self._view
        if (v is None or v.remaining is not self.remaining
                or v.tables.shape[0] != self.n_rows):
            # ``alive`` is rebound together with ``remaining`` when the
            # arrays grow, so the identity check above covers the ctx
            # fields too
            ctx.alive = self.alive
            ctx.prefix = self._prefix
            v = self._view = sched.AllocView(
                remaining=self.remaining,
                tables=self.tables[:self.n_rows],
                max_w=self.max_w,
                explore_started=self.explore_started,
                rows=self.rows,
                placement=placement,
                live=self.alive, lo=self.lo, hi=self.hi,
                n_live=self.n,
                inc=ctx)
        else:
            v.placement = placement
            v.lo = self.lo
            v.hi = self.hi
            v.n_live = self.n
        return v

    def dense_view(self, ls: np.ndarray, placement=None) -> sched.AllocView:
        """A dense (reference-shaped) view gathered over live slots
        ``ls`` — the compatibility shim for non-slotted policies, which
        keep the plain dense-target ``allocate`` contract."""
        return sched.AllocView(remaining=self.remaining[ls],
                               tables=self.tables[:self.n_rows],
                               max_w=self.max_w[ls],
                               explore_started=self.explore_started[ls],
                               rows=self.rows[ls],
                               placement=placement)


def _simulate_table(jobs: list[JobSpec], cluster: ClusterModel,
                    policy: sched.SchedulingPolicy,
                    tel: object = _tele.NULL) -> SimResult:
    capacity = cluster.capacity
    restart_cost = cluster.restart_cost
    penalty = cluster.contention_penalty
    peng = None
    if cluster.placement is not None:
        from repro.core.placement import PlacementEngine
        peng = PlacementEngine(cluster)
    pending = sorted(jobs, key=lambda j: j.arrival)
    n_jobs = len(pending)
    pi = 0                        # next-arrival cursor into `pending`
    # Fault injection (PR 10): one deterministic incident tape per
    # (cluster, fault_seed), delivered by a sorted cursor exactly like
    # arrivals.  Empty on fault-free clusters — the per-event cost is a
    # single int compare and the trajectory is bit-identical to pre-fault
    # code (gated by the goldens).
    fsched: tuple = ()
    ckpt = None
    if cluster.faults is not None:
        from repro.core.faults import CheckpointPolicy, get_fault_model
        horizon = pending[-1].arrival if pending else 0.0
        fsched = get_fault_model(cluster.faults).schedule(
            cluster, cluster.fault_seed, horizon)
        ckpt = CheckpointPolicy(
            interval=(cluster.checkpoint_interval
                      if cluster.checkpoint_interval is not None
                      else CheckpointPolicy.interval),
            restart_cost=cluster.restart_cost)
    nf = len(fsched)
    fi = 0                        # next-fault cursor into `fsched`
    requeue_rem: dict[int, float] = {}  # evicted job -> remaining at requeue
    evictions = 0
    # slots don't carry specs; eviction-requeue needs them back
    spec_by_id = {j.job_id: j for j in jobs} if nf else None
    st = _SoAState(table_width=capacity + 1)
    # telemetry: one recorder per run; hot paths pay a single ``rec_on``
    # check when disabled (``rec`` is the module no-op singleton then)
    rec = tel.recorder(policy.spec, capacity, n_jobs,
                       getattr(cluster, "gpus_per_node", 0) or 0)
    rec_on = rec.on
    # solve-timer handle hoisted out of the event loop (bound method:
    # one call per reallocation instead of two attribute chases + call)
    t_solve_add = rec.t_solve.add if rec_on else None
    st.ctx.tel = rec.registry
    if peng is not None:
        peng.rec = rec
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    delayed: list[JobSpec] = []   # admission-delayed, retried every event
    rejected: list[int] = []
    now = 0.0
    peak = 0
    next_resched = 0.0
    static_key: tuple | None = None
    static_target: np.ndarray | None = None
    # Static-event queue: restart-freeze expiries only, bucketed by tick
    # period, with lazy invalidation (stale entries are discarded at
    # peek time).  The reschedule tick needs no queue at all — it is
    # always exactly ``next_resched``, a scalar.
    events = _CalendarQueue(RESCHEDULE_EVERY)
    # Dirty-slice bookkeeping: at most `capacity` jobs hold workers at
    # once, so per-event scans (estimates, advance, unfreeze checks,
    # contention counts) run over `run` — the slots with w > 0 —
    # instead of the thousands of queued w=0 rows a saturated 10k-job
    # trace carries.  `run` is maintained *incrementally* from the
    # sparse allocation deltas (and the cached communicating-job count
    # with it); `fresh` holds slots admitted since the last completion
    # scan, the only other rows whose remaining work could newly sit at
    # <= 0.
    run = np.empty(0, np.int64)
    run_list: list[int] = []      # same slots as plain ints, sorted
    nr = 0                        # == len(run_list)
    run_set: set[int] = set()
    comm_n = 0
    fresh: list[int] = []
    # Under fault injection the applied allocation can be clamped below
    # what the solver asked for (surviving capacity), and evictions can
    # change membership without moving the (hi, done) static key — both
    # silently diverge a slotted solver's persistent incremental state
    # (or a static policy's cached target) from the engine's ground
    # truth.  Churn runs force the stateless dense contract instead;
    # fault-free runs keep every fast path (gated by the goldens).
    use_slotted = policy.slotted and not nf
    # Below this run-set size the per-event estimate/advance/completion
    # pass runs as a scalar Python loop instead of vectorized numpy —
    # same IEEE-754 ops element by element (gather/divide/multiply/
    # subtract and an exact min), so the trajectory is bit-identical,
    # but without the ~1-2 µs fixed cost per array op that dominates
    # when only a handful of jobs hold workers (srtf runs ~8 winners on
    # a 64-GPU cluster; the vector path keeps winning at ~64).
    small_run = 16 if peng is None else -1
    sp_l: list[float] = []        # effective speed per run_list slot
    speed_eff = np.empty(0)       # effective speed per run entry
    fac_ok = True                 # contention factor > 0 for this run set
    # Scalar run summaries.  ``max_frz`` is a conservative upper bound
    # on every running job's restart-freeze expiry: bumped at freeze
    # time, never recomputed (a member leaving ``run`` can only lower
    # the true max, so the bound stays sound and self-heals as time
    # passes it).  With max_frz in the past — the steady state; freezes
    # are short — the per-event estimate/advance pass needs no frozen
    # gather and no masking at all.  ``spd_ok`` (every running job has
    # nonzero speed) is the interned-table positivity flag on flat
    # clusters and a per-refresh check under a placement engine, whose
    # factors can zero a speed.
    max_frz = 0.0
    spd_ok = True

    def refresh_speed() -> None:
        """Re-derive the run set's *effective* speeds (contention factor
        folded in) once per membership/allocation change.  Every input —
        ``speed_now``, ``w``, ``spanning``, ``comm_n`` — only moves
        right before a refresh, so caching here is value-identical to
        the old per-event recompute (same ops on the same floats), and
        the per-event pass shrinks to a divide and a min."""
        nonlocal sp_l, speed_eff, fac_ok
        fac = cluster.contention_factor(comm_n) if penalty else 1.0
        fac_ok = fac > 0.0
        if nr <= small_run:
            spd = st.speed_now
            if fac != 1.0:
                wv = st.w
                sp_l = [spd[s] * fac if wv[s] >= 2 else spd[s]
                        for s in run_list]
            else:
                sp_l = [spd[s] for s in run_list]
        else:
            sr = st.speed_now[run]
            if fac != 1.0:
                comm = (st.spanning[run] if peng is not None
                        else st.w[run] >= 2)
                sr = np.where(comm, sr * fac, sr)
            speed_eff = sr

    def refresh_run_from_set() -> None:
        """Rebuild the sorted run list from the incrementally-updated
        slot set — O(|run| log |run|) with |run| <= capacity, never
        O(active jobs).  The ndarray twin is only materialized above the
        scalar-loop threshold.  Flat clusters only (placement recomputes
        dense)."""
        nonlocal run, run_list, nr, comm_n
        run_list = sorted(run_set)
        nr = len(run_list)
        if nr > small_run:
            run = np.fromiter(run_list, np.int64, nr)
        if penalty:
            if nr <= small_run:
                wv = st.w
                comm_n = sum(1 for s in run_list if wv[s] >= 2)
            else:
                comm_n = int((st.w[run] >= 2).sum())
        refresh_speed()

    def refresh_run_dense(ls: np.ndarray | None = None) -> None:
        nonlocal run, run_list, nr, comm_n, spd_ok
        if ls is None:
            ls = st.live_slots()
        w = st.w[ls]
        run = ls[w > 0]
        run_list = run.tolist()
        nr = len(run_list)
        run_set.clear()
        run_set.update(run_list)
        if penalty:
            comm_n = (int(st.spanning[ls].sum()) if peng is not None
                      else int((w >= 2).sum()))
        if peng is not None:
            spd_ok = (bool((st.speed_now[run] > 0.0).all()) if len(run)
                      else True)
        refresh_speed()

    def solve_dense(ls: np.ndarray, pv, now: float) -> np.ndarray:
        """A dense live-ordered target from the policy: non-slotted
        policies return one natively; slotted policies' sparse deltas
        are materialized into the context's reused scratch buffer (the
        placement pass needs the full gang vector)."""
        if not use_slotted:
            return policy.allocate(st.dense_view(ls, pv), cluster, now)
        delta = policy.allocate(st.view(pv), cluster, now)
        target = st.ctx.scratch(len(ls))
        target[:] = st.w[ls]
        if len(delta.slots):
            target[np.searchsorted(ls, delta.slots)] = delta.w
        return target

    p_allocate = policy.allocate
    p_static = policy.static and not nf
    slotted_fast = peng is None and use_slotted
    st_view = st.view

    def apply_alloc(now: float) -> None:
        nonlocal static_key, static_target, max_frz
        if slotted_fast:
            # the sparse fast path: the policy names the slots that may
            # have moved; everything else keeps its allocation — O(Δ)
            # per tick, no dense target, no full-width compare
            if p_static:
                # a static policy's target depends only on the active
                # set's identity/order: with the (admissions,
                # completions) key unchanged the applied allocation is
                # already the target.  The monotone counter pair
                # identifies the set: any membership change moves one.
                key = (st.hi, len(done))
                if key == static_key:
                    if rec_on:
                        rec.solve_reused()
                    return
                static_key = key
            delta = p_allocate(st_view(None), cluster, now)
            tslots, tw = delta.slots, delta.w
            if not len(tslots):
                if rec_on:
                    rec.solve_reused()
                return
            cur = st.w[tslots]
            chm = tw != cur
            if not chm.any():
                if rec_on:
                    rec.solve_reused()
                return
            gs = tslots[chm]
            wn = tw[chm]
            gs_l = gs.tolist()
            wn_l = wn.tolist()
            if rec_on:
                rec.solve(now, len(gs_l), False, st.n)
                for jid, ov, nv in zip(st.ids[gs].tolist(),
                                       cur[chm].tolist(), wn_l):
                    rec.alloc(now, jid, ov, nv)
            st.w[gs] = wn
            st.speed_now[gs] = st.tables[st.rows[gs], wn]
            for s, wv in zip(gs_l, wn_l):
                if wv > 0:
                    run_set.add(s)
                else:
                    run_set.discard(s)
            refresh_run_from_set()
            started = gs[wn > 0]
        else:
            pv = None if peng is None else peng.view()
            ls = st.live_slots()
            if p_static:
                key = (st.hi, len(done))
                if key != static_key:
                    static_key = key
                    # cached across events: copy out of the scratch
                    # buffer the next solve would overwrite
                    static_target = solve_dense(ls, pv, now).copy()
                target = static_target
            else:
                target = solve_dense(ls, pv, now)
            changed = np.nonzero(target != st.w[ls])[0]
            if peng is None:
                if not len(changed):
                    if rec_on:
                        rec.solve_reused()
                    return
                gi = ls[changed]
                if rec_on:
                    rec.solve(now, len(changed), False, st.n)
                    ids_ = st.ids
                    oldw = st.w[gi].tolist()
                    for s, ov, nv in zip(gi.tolist(), oldw,
                                         target[changed].tolist()):
                        rec.alloc(now, int(ids_[s]), ov, nv)
                st.w[gi] = target[changed]
                st.speed_now[gi] = st.tables[st.rows[gi], target[changed]]
                started = gi[target[changed] > 0]
            else:
                # placement pass runs even when no target changed: a
                # completion may have opened a defrag/consolidation move
                if rec_on:
                    if len(changed):
                        rec.solve(now, len(changed), False, st.n)
                    else:
                        rec.solve_reused()
                    oldw = st.w[ls[changed]].tolist()
                upd, factors, spans = peng.apply(st.ids[ls], target,
                                                 changed.tolist(), now)
                # alloc events fire after apply: under faults the engine
                # clamps grants to surviving capacity in-place, and the
                # logged width must be what the gang actually got
                if rec_on:
                    ids_ = st.ids
                    for s, ov, nv in zip(ls[changed].tolist(), oldw,
                                         target[changed].tolist()):
                        rec.alloc(now, int(ids_[s]), ov, nv)
                st.w[ls] = target
                if not len(upd):
                    return
                gi = ls[upd]
                st.place_factor[gi] = factors
                st.spanning[gi] = spans
                st.speed_now[gi] = (st.tables[st.rows[gi], target[upd]]
                                    * factors)
                started = gi[target[upd] > 0]
            refresh_run_dense(ls)
        until = now + restart_cost
        # batched restart freeze: every job whose allocation changed
        # unfreezes at the same instant, so one queue entry covers them
        # all (the per-job push loop was the last Python loop here)
        if len(started):
            st.frozen[started] = until
            if until > max_frz:
                max_frz = until
            events.push(until, _EV_UNFREEZE)
            if rec_on:
                for jid in st.ids[started].tolist():
                    rec.freeze(now, jid, until)

    stall = 0
    while pi < n_jobs or st.n or delayed:
        now0 = now
        popped = False
        # --- next event time -------------------------------------------
        # discard stale unfreeze events, then take the earlier of the
        # first valid one and the reschedule tick
        while True:
            head = events.peek()
            if head is None:
                t = next_resched
                break
            t = head[0]
            # batched unfreeze: valid while any live allocated job
            # still thaws exactly at t (re-freezes move `frozen` past
            # t and completions drop rows — either stales the entry).
            # The max_frz bound short-circuits the scan: t above it
            # can match nothing.
            if t > now and nr and t <= max_frz:
                if nr <= small_run:
                    frz = st.frozen
                    if any(frz[s] == t for s in run_list):
                        break
                elif bool(np.any(st.frozen[run] == t)):
                    break
            events.pop()
            popped = True
        if next_resched < t:
            t = next_resched
        t_min = t
        if pi < n_jobs and pending[pi].arrival < t_min:
            t_min = pending[pi].arrival
        if fi < nf and fsched[fi].t < t_min:
            t_min = fsched[fi].t
        # completion estimates are recomputed from (now, remaining) every
        # event on purpose — see module docstring (bit-identical
        # trajectory); only the w>0 slice can run, so only it is scanned
        frozen_r = speed_r = None
        fastp = False
        adv = None
        scalar = False
        comp_l: list[int] = _NO_COMP
        if nr and nr <= small_run:
            # scalar twin of the vectorized pass below: same per-element
            # IEEE ops (max/divide then an exact min; multiply/subtract
            # on advance), so every remaining-work value and completion
            # estimate carries the same bits — just without ~10 array-op
            # dispatches for a handful of running jobs.  The completion
            # threshold is checked on the freshly-written value inside
            # the advance loop — the same <= 1e-9 compare the vector
            # path runs as a separate candidate scan.
            scalar = True
            remv = st.remaining
            frz = st.frozen
            no_frz = max_frz <= now
            x_min = math.inf
            for i, s in enumerate(run_list):
                sv = sp_l[i]
                if sv > 0.0 and (no_frz or frz[s] <= now):
                    x = remv[s] / sv
                    if x < x_min:
                        x_min = x
            if x_min < math.inf:
                e_min = now + x_min
                if e_min < t_min:
                    t_min = e_min
            t_next = now if t_min < now else t_min
            if t_next > now:
                for i, s in enumerate(run_list):
                    f0 = frz[s]
                    dt = t_next - (f0 if f0 > now else now)
                    if dt > 0.0:
                        rv = remv[s] - dt * sp_l[i]
                        remv[s] = rv
                        if rv <= 1e-9:
                            if comp_l is _NO_COMP:
                                comp_l = [s]
                            else:
                                comp_l.append(s)
            now = t_next
        elif nr:
            # GADGET-style link sharing is folded into ``speed_eff`` at
            # refresh time: every concurrently-allocated ring job
            # (w >= 2, frozen or not — it holds its links) runs at
            # contention_factor(k) of nominal speed; under a placement
            # engine only *actually node-spanning* rings contend.
            speed_r = speed_eff
            spd_ok_now = spd_ok and fac_ok
            if peng is None:
                spd_ok_now = spd_ok_now and st.tables_pos
            if max_frz <= now and spd_ok_now:
                # nothing frozen, everything runnable: the select mask
                # is provably all-True, so skip building it.  min(now +
                # x_i) == now + min(x_i) exactly (monotone rounding), so
                # the full-width add is skipped too — bits unchanged.
                fastp = True
                e_min = now + (st.remaining[run] / speed_r).min()
                if e_min < t_min:
                    t_min = e_min
            else:
                frozen_r = st.frozen[run]
                sel = (frozen_r <= now) & (speed_r > 0.0)
                if sel.any():
                    est = now + st.remaining[run[sel]] / speed_r[sel]
                    e_min = est.min()
                    if e_min < t_min:
                        t_min = e_min
        if not scalar:
            # --- advance progress (vector twin) --------------------------
            t_next = now if t_min < now else t_min
            if nr:
                if fastp:
                    dts = t_next - now
                    if dts > 0.0:
                        adv = run
                        st.remaining[run] -= dts * speed_r
                else:
                    dt = t_next - np.maximum(frozen_r, now)
                    pos = dt > 0.0
                    if pos.any():
                        adv = run[pos]
                        st.remaining[adv] -= dt[pos] * speed_r[pos]
            now = t_next

        # --- completions -------------------------------------------------
        # only rows that advanced (or were just admitted) can newly reach
        # the threshold — the dirty slice of the old full-width scan
        finished = False
        glist: list[int] | None = None
        if scalar:
            if fresh:
                # fresh (just-admitted) slots use the same threshold —
                # dedupe against the advance-loop finds
                remv = st.remaining
                cl = comp_l + [s for s in fresh if remv[s] <= 1e-9]
                fresh = []
                if cl:
                    glist = sorted(set(cl))
            elif comp_l is not _NO_COMP:
                glist = comp_l        # ascending already: run_list order
        else:
            if fresh:
                cand = (np.asarray(fresh, np.int64) if adv is None
                        else np.concatenate((adv,
                                             np.asarray(fresh, np.int64))))
                fresh = []
            else:
                cand = adv
            if cand is not None and len(cand):
                fin = st.remaining[cand] <= 1e-9
                if fin.any():
                    # ascending slots == arrival order, like the old dense
                    # scan (python set/sort beats np.unique at these sizes)
                    glist = sorted(set(cand[fin].tolist()))
        if glist is not None:
            finished = True
            for i in glist:
                jid = int(st.ids[i])
                done[jid] = now
                if peng is not None:
                    peng.release(jid)
                if rec_on:
                    rec.complete(now, jid)
            st.remove(glist)
            if peng is None:
                for i in glist:
                    run_set.discard(i)
                refresh_run_from_set()
            else:
                refresh_run_dense()

        # --- faults ------------------------------------------------------
        # incidents due at `now` fire after completions (a job that
        # finished at the kill instant keeps its finish) and before
        # arrivals/reallocation, so the next solve sees the shrunk
        # cluster.  `fi < nf` is the only cost on fault-free runs.
        faulted = False
        while fi < nf and fsched[fi].t <= now + 1e-9:
            fe = fsched[fi]
            fi += 1
            faulted = True
            if rec_on:
                rec.fault(now, fe.node, fe.kind)
            if fe.kind == "fail":
                victims = peng.fail(fe.node)
                if victims:
                    vset = set(victims)
                    ids_ = st.ids
                    remv = st.remaining
                    # ascending live slots == reference active-list order
                    vslots = [s for s in st.live_slots().tolist()
                              if int(ids_[s]) in vset]
                    evicted = []
                    for s in vslots:
                        jid = int(ids_[s])
                        spec = spec_by_id[jid]
                        done_p = spec.epochs - float(remv[s])
                        lost = ckpt.lost_progress(done_p)
                        evicted.append(
                            (jid, spec, float(remv[s]) + lost, lost,
                             lost / done_p if done_p > 0.0 else 0.0))
                    st.remove(vslots)
                    evictions += len(vslots)
                    # killed gangs lose un-checkpointed progress and
                    # re-enter through the normal admission path
                    for jid, spec, new_rem, lost, lost_frac in evicted:
                        if rec_on:
                            rec.evict(now, jid, fe.node, lost, lost_frac)
                        requeue_rem[jid] = new_rem
                        verdict = peng.admit(spec, st.n, len(delayed), now)
                        if verdict == "admit":
                            s2 = st.add(spec, spec.speed_table(cluster),
                                        now if policy.explores else None)
                            st.remaining[s2] = requeue_rem.pop(jid)
                            fresh.append(s2)
                            peng.register(spec)
                            if rec_on:
                                rec.recover(now, jid)
                        elif verdict == "reject":
                            requeue_rem.pop(jid)
                            rejected.append(jid)
                            if rec_on:
                                rec.reject(now, jid)
                        else:
                            delayed.append(spec)
                            if rec_on:
                                rec.delay(now, jid)
                    refresh_run_dense()
            elif fe.kind == "drain":
                peng.drain(fe.node)
            elif fe.kind == "recover":
                peng.recover(fe.node)
            else:
                peng.degrade(fe.node, fe.factor)

        # --- arrivals ----------------------------------------------------
        arrived = False
        if delayed:
            # admission-delayed jobs are retried first at every event
            # (they arrived before anything admitted below)
            still: list[JobSpec] = []
            for j in delayed:
                verdict = peng.admit(j, st.n, len(still), now)
                if verdict == "admit":
                    s2 = st.add(j, j.speed_table(cluster),
                                now if policy.explores else None)
                    if requeue_rem:
                        # evicted-then-delayed: resume from the rolled-back
                        # progress, not from scratch
                        rr = requeue_rem.pop(j.job_id, None)
                        if rr is not None:
                            st.remaining[s2] = rr
                    fresh.append(s2)
                    peng.register(j)
                    arrived = True
                    if rec_on:
                        rec.admit(now, j.job_id)
                elif verdict == "reject":
                    rejected.append(j.job_id)
                    if rec_on:
                        rec.reject(now, j.job_id)
                else:
                    still.append(j)
            if still and not arrived and not st.n and pi == n_jobs:
                raise RuntimeError(
                    f"admission rule {cluster.admission!r} stalled: "
                    f"{len(still)} delayed jobs on an idle cluster")
            delayed = still
        while pi < n_jobs and pending[pi].arrival <= now + 1e-9:
            j = pending[pi]
            pi += 1
            if rec_on:
                rec.submit(now, j.job_id, j.arrival)
            if peng is not None:
                verdict = peng.admit(j, st.n, len(delayed), now)
                if verdict == "delay":
                    delayed.append(j)
                    if rec_on:
                        rec.delay(now, j.job_id)
                    continue
                if verdict == "reject":
                    rejected.append(j.job_id)
                    if rec_on:
                        rec.reject(now, j.job_id)
                    continue
                peng.register(j)
            # the cluster-keyed table row (flat clusters share the int-path
            # cache, so this is the exact seed table); sized to `capacity`,
            # not j.max_w: j.max_w may exceed the cluster (mixed fleets),
            # and a capacity-sized row makes every interned table row the
            # same width — the solver never probes past
            # min(j.max_w, capacity) anyway.
            fresh.append(st.add(j, j.speed_table(cluster),
                                now if policy.explores else None))
            arrived = True
            if rec_on:
                rec.admit(now, j.job_id)

        if st.n > peak:
            peak = st.n

        # --- reallocation ------------------------------------------------
        rescheduled = False
        if arrived or finished or faulted or now + 1e-9 >= next_resched:
            if st.n:
                if rec_on:
                    _t0 = perf_counter()
                    apply_alloc(now)
                    t_solve_add(perf_counter() - _t0)
                else:
                    apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY
            rescheduled = True

        # --- termination guard (sub-ulp completion estimates) ------------
        # Once the clock passes ~2^28 s, the shortest completion estimate
        # can round to exactly ``now`` (x_min < ulp(now)/2): then
        # t_next == now, dt == 0, remaining work never advances, and with
        # no arrival / completion / queue pop / reschedule the iteration
        # is a deterministic fixed point — the loop (and the seed loop,
        # which computes the same doubles) would spin forever.  Three
        # consecutive inert iterations prove the fixed point (one
        # repetition already would, but the calendar cursor may still be
        # settling on the first); the jobs whose estimate rounds to
        # ``now`` then complete AT ``now`` — the event time an explicit
        # completion-event queue would have fired at after the same
        # rounding.  A trace that terminates without this guard never
        # runs even one repeated inert iteration, so every
        # previously-terminating trajectory is bit-identical.
        if (arrived or finished or faulted or popped or rescheduled
                or now > now0):
            stall = 0
        else:
            stall += 1
            if stall >= 3:
                remv = st.remaining
                frz = st.frozen
                idle = max_frz <= now
                stuck = []
                for i, s in enumerate(run_list):
                    sv = (sp_l[i] if nr <= small_run
                          else float(speed_eff[i]))
                    if (sv > 0.0 and (idle or frz[s] <= now)
                            and now + remv[s] / sv == now):
                        stuck.append(s)
                if not stuck:
                    raise RuntimeError(
                        f"event loop stalled at t={now!r} with no "
                        f"sub-ulp completion candidate")
                for s in stuck:
                    remv[s] = 0.0
                # ride the just-admitted completion scan: the next
                # event's candidate pass unions ``fresh`` with the
                # advanced rows and applies the same <= 1e-9 threshold
                fresh.extend(stuck)
                stall = 0

    return SimResult(strategy=policy.spec, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak,
                     rejected=tuple(rejected),
                     migrations=0 if peng is None else peng.migrations,
                     evictions=evictions,
                     telemetry=rec.finish(now))


# The paper's Table-3 strategy sweep, plus the registry extensions.
TABLE3_STRATEGIES = ("precompute", "exploratory", "fixed_8", "fixed_4",
                     "fixed_2", "fixed_1", "srtf", "utility_greedy")


def run_table3(seed: int = 0, capacity: int | None = None,
               contention: dict[str, tuple[float, int]] | None = None,
               engine: str = "table",
               pattern: str = "poisson",
               strategies: tuple[str, ...] | None = None,
               cluster: ClusterModel | None = None
               ) -> dict[str, dict[str, float]]:
    """Reproduce Table 3: avg JCT (hours) per strategy x contention level.

    ``pattern`` selects the arrival/size process from the workload-pattern
    library (``jobs.WORKLOAD_PATTERNS``); the paper's own Table 3 is the
    default ``"poisson"`` trace.  ``strategies`` defaults to the paper's
    six plus the registry extensions (srtf, utility_greedy); ``cluster``
    swaps the flat 64-GPU cluster for any :class:`ClusterModel` (e.g. a
    multi-node topology with a contention penalty).
    """
    from repro.core.jobs import make_workload
    contention = contention or {"extreme": (250.0, 206),
                                "moderate": (500.0, 114),
                                "none": (1000.0, 44)}
    strategies = TABLE3_STRATEGIES if strategies is None else strategies
    out: dict[str, dict[str, float]] = {}
    for level, (gap, n_jobs) in contention.items():
        jobs = make_workload(pattern, n_jobs, gap, seed)
        out[level] = {}
        for s in strategies:
            res = simulate(jobs, capacity, s, engine=engine, cluster=cluster)
            out[level][s] = res.avg_jct_hours
    return out
