"""Cluster scheduler simulation — paper §7.

Event-driven simulation of a C-GPU cluster with Poisson job arrivals.
Strategies (Table 3): ``precompute``, ``exploratory``, and fixed 1/2/4/8.
Reallocation happens at arrivals, completions and periodic intervals; every
allocation change costs the measured checkpoint-stop-restart pause (~10 s,
§6).  The exploratory strategy gives a new job 8 GPUs for its first ten
minutes, running 2.5 min at each of 1, 2, 4, 8 GPUs to collect the (w, f(w))
points the resource model (eq. 5) needs.

Two engines, one trajectory:

  * ``engine="table"`` (default) — the hot path, structure-of-arrays.  The
    active set lives in ``_SoAState``: numpy ``remaining`` / ``w`` /
    ``frozen`` / ``speed_now`` arrays plus a 2-D speed-table matrix, all in
    reference active-list order (order is load-bearing for tie-breaks and
    FIFO grants), maintained incrementally — rows append on arrival
    (doubling growth) and compact in place on completion, never rebuilt per
    tick.  Each job's speed curve is sampled once into a table row at
    admission (``JobSpec.speed_table`` is bit-identical to per-scalar
    ``speed`` calls), allocation is solved by the SoA lazy-heap solvers
    (``scheduler.doubling_heuristic_soa`` — no per-job tuples), the
    per-event completion-estimate scan and progress advance are vectorized
    slices, deterministic events (reschedule ticks, restart-freeze
    expiries) live in a heapq with lazy invalidation, and the next arrival
    is an index into the time-sorted job list.  This is what makes
    1000-job traces finish in well under a second per strategy.
    Completion estimates are deliberately *recomputed* each event: the
    trajectory ``remaining -= dt * speed`` re-derives the completion time
    from the current (now, remaining) pair at every event, so a cached
    completion event would drift from the reference by one ulp per tick —
    recomputation is what keeps the two engines bit-identical.  Pure
    reschedule ticks skip re-solving only for ``fixed_k`` strategies, where
    the target provably depends on nothing but the active-set order; the
    dynamic strategies re-solve every tick because the doubling gains move
    with ``remaining`` (on the Table-3 workloads ~20% of same-active-set
    re-solves change the target, so skipping them would change results).
  * ``engine="reference"`` — the original O(J)-rescan loop kept verbatim as
    the parity oracle and the "seed" side of benchmarks/bench_scheduler.py.

Both engines share the exploratory-phase gang-grant clamp (a job entering
its explore phase reserves ``min(8, remaining capacity)`` instead of the
old all-or-nothing 8/0 grant, which starved later explorers outright).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import scheduler as sched
from repro.core.jobs import JobSpec

RESTART_COST = 10.0          # seconds (paper §6)
EXPLORE_SEGMENT = 150.0      # 2.5 minutes at each of 1, 2, 4, 8 (§7)
EXPLORE_WS = (1, 2, 4, 8)
RESCHEDULE_EVERY = 150.0


@dataclasses.dataclass
class _Active:
    spec: JobSpec
    remaining: float              # epochs
    w: int = 0
    frozen_until: float = 0.0     # restart pause
    explore_started: float | None = None
    # speed table sampled once at admission; only the _allocate_table
    # parity oracle reads it now — the fast engine keeps tables in
    # _SoAState.tables instead
    table: list | None = None

    def explore_w(self, now: float) -> int | None:
        """Worker count dictated by the explore phase, or None if done."""
        if self.explore_started is None:
            return None
        seg = int((now - self.explore_started) // EXPLORE_SEGMENT)
        if seg >= len(EXPLORE_WS):
            return None
        return EXPLORE_WS[seg]

    def speed(self, now: float) -> float:
        if now < self.frozen_until or self.w <= 0:
            return 0.0
        return self.spec.speed(self.w)


@dataclasses.dataclass
class SimResult:
    strategy: str
    completion_times: dict[int, float]
    arrival_times: dict[int, float]
    peak_concurrency: int

    @property
    def avg_jct_hours(self) -> float:
        jcts = [self.completion_times[j] - self.arrival_times[j]
                for j in self.completion_times]
        return float(np.mean(jcts)) / 3600.0


def _explore_grants(active: list[_Active], capacity: int, now: float,
                    alloc: dict[int, int], dynamic: list[_Active]) -> int:
    """Grant explore-phase jobs their gang reservation; returns leftover cap.

    Each profiling job reserves a gang of ``min(8, remaining capacity)``
    GPUs (clamped — the old all-or-nothing 8 grant handed later explorers
    exactly 0 and kept them out of the dynamic pool, silently starving
    them) and runs its schedule-dictated w inside that reservation.
    """
    cap = capacity
    for a in active:
        ew = a.explore_w(now)
        if ew is not None:
            grant = min(8, cap)
            alloc[a.spec.job_id] = min(ew, grant)
            cap -= grant
        else:
            dynamic.append(a)
    return cap


def _allocate(strategy: str, active: list[_Active], capacity: int,
              now: float) -> dict[int, int]:
    """Target allocation for the current set of active jobs (callable path,
    reference engine)."""
    if strategy.startswith("fixed"):
        k = int(strategy.split("_")[1])
        tuples = [(a.spec.job_id, a.remaining, a.spec.speed) for a in active]
        return sched.fixed(tuples, capacity, k)

    alloc: dict[int, int] = {}
    dynamic: list[_Active] = []
    if strategy == "exploratory":
        cap = _explore_grants(active, capacity, now, alloc, dynamic)
    else:  # precompute: all jobs schedulable immediately
        cap = capacity
        dynamic = list(active)
    tuples = [(a.spec.job_id, a.remaining, a.spec.speed) for a in dynamic]
    alloc.update(sched.doubling_heuristic_ref(
        tuples, cap, max_w=[a.spec.max_w for a in dynamic]))
    return alloc


def _allocate_table(strategy: str, active: list[_Active], capacity: int,
                    now: float) -> dict[int, int]:
    """Target allocation from cached speed tables over ``_Active`` lists.

    No longer on the hot path (the fast engine allocates through
    ``_allocate_soa``); kept as a second parity oracle between the tuple
    and SoA layers, exercised by the explore-grant tests.
    """
    if strategy.startswith("fixed"):
        k = int(strategy.split("_")[1])
        tuples = [(a.spec.job_id, a.remaining, None) for a in active]
        return sched.fixed(tuples, capacity, k)

    alloc: dict[int, int] = {}
    dynamic: list[_Active] = []
    if strategy == "exploratory":
        cap = _explore_grants(active, capacity, now, alloc, dynamic)
    else:
        cap = capacity
        dynamic = active
    assert cap >= 0, "explore gang grants exceeded cluster capacity"
    tuples = [(a.spec.job_id, a.remaining, a.table) for a in dynamic]
    alloc.update(sched.doubling_heuristic_table(
        tuples, cap, max_w=[a.spec.max_w for a in dynamic]))
    return alloc


def simulate(jobs: list[JobSpec], capacity: int = 64,
             strategy: str = "precompute", engine: str = "table") -> SimResult:
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if strategy.startswith("fixed"):
        # stall guard: an unsatisfiable gang size means every job gets the
        # all-or-nothing 0 grant forever and the event loop would tick on
        # reschedules for eternity
        k = int(strategy.split("_")[1])
        if not 1 <= k <= capacity:
            raise ValueError(
                f"{strategy!r} can never run a job on a {capacity}-GPU "
                f"cluster (gang size must be in [1, capacity])")
    if engine == "table":
        return _simulate_table(jobs, capacity, strategy)
    if engine == "reference":
        return _simulate_reference(jobs, capacity, strategy)
    raise ValueError(f"unknown engine {engine!r}")


# Event kinds in the fast engine's static-event heap.
_EV_RESCHED = 0
_EV_UNFREEZE = 1


class _SoAState:
    """Order-preserving structure-of-arrays active set (fast engine).

    One row per active job, in the same order the reference engine keeps
    its ``active`` list (arrival order with in-place removals) — the order
    is load-bearing: solver tie-breaks, FIFO fixed grants and explore-gang
    grants all key off it.  Arrays grow by doubling on arrival and compact
    in place on completion, so per-event work is vectorized slices instead
    of rebuilt per-job tuples.
    """

    __slots__ = ("n", "ids", "remaining", "w", "frozen", "speed_now",
                 "explore_started", "max_w", "tables", "index_of")

    def __init__(self, table_width: int, cap: int = 16):
        self.n = 0
        self.ids = np.zeros(cap, np.int64)
        self.remaining = np.zeros(cap)
        self.w = np.zeros(cap, np.int64)
        self.frozen = np.zeros(cap)
        self.speed_now = np.zeros(cap)      # tables[i, w[i]] (0 when w == 0)
        self.explore_started = np.full(cap, -np.inf)
        self.max_w = np.zeros(cap, np.int64)
        self.tables = np.zeros((cap, table_width))
        self.index_of: dict[int, int] = {}

    def _grow(self) -> None:
        cap = 2 * len(self.ids)
        for name in ("ids", "remaining", "w", "frozen", "speed_now",
                     "explore_started", "max_w"):
            old = getattr(self, name)
            new = np.zeros(cap, old.dtype)
            new[:self.n] = old[:self.n]
            setattr(self, name, new)
        tables = np.zeros((cap, self.tables.shape[1]))
        tables[:self.n] = self.tables[:self.n]
        self.tables = tables

    def add(self, spec: JobSpec, table_row: np.ndarray,
            explore_started: float | None) -> None:
        i = self.n
        if i == len(self.ids):
            self._grow()
        self.ids[i] = spec.job_id
        self.remaining[i] = spec.epochs
        self.w[i] = 0
        self.frozen[i] = 0.0
        self.speed_now[i] = 0.0
        self.explore_started[i] = (-np.inf if explore_started is None
                                   else explore_started)
        self.max_w[i] = spec.max_w
        self.tables[i, :] = table_row
        self.index_of[spec.job_id] = i
        self.n = i + 1

    def compact(self, keep: np.ndarray) -> None:
        """Drop rows where ``keep`` is False, preserving relative order."""
        n = self.n
        idx = np.nonzero(keep)[0]
        m = len(idx)
        for name in ("ids", "remaining", "w", "frozen", "speed_now",
                     "explore_started", "max_w"):
            arr = getattr(self, name)
            arr[:m] = arr[:n][idx]
        self.tables[:m] = self.tables[:n][idx]
        self.n = m
        self.index_of = {int(self.ids[i]): i for i in range(m)}


def _allocate_soa(strategy: str, st: _SoAState, capacity: int,
                  now: float) -> np.ndarray:
    """Target allocation over the SoA active set (fast engine).

    Same semantics (and bit-identical results) as ``_allocate`` /
    ``_allocate_table``, but in and out are arrays aligned with the
    active-set order — nothing per-job is materialized on the hot path.
    """
    n = st.n
    if strategy.startswith("fixed"):
        return sched.fixed_soa(n, capacity, int(strategy.split("_")[1]))

    if strategy == "exploratory":
        cap = capacity
        target = np.zeros(n, np.int64)
        seg = (now - st.explore_started[:n]) // EXPLORE_SEGMENT
        explorer = seg < len(EXPLORE_WS)
        for i in np.nonzero(explorer)[0]:
            grant = min(8, cap)
            target[i] = min(EXPLORE_WS[int(seg[i])], grant)
            cap -= grant
        assert cap >= 0, "explore gang grants exceeded cluster capacity"
        rows = np.nonzero(~explorer)[0]
        target[rows] = sched.doubling_heuristic_soa(
            st.remaining[:n][rows], st.tables, cap,
            max_w=st.max_w[:n][rows], rows=rows)
        return target
    # precompute: all jobs schedulable immediately (rows=None -> row i)
    return sched.doubling_heuristic_soa(st.remaining[:n], st.tables,
                                        capacity, max_w=st.max_w[:n])


def _simulate_table(jobs: list[JobSpec], capacity: int,
                    strategy: str) -> SimResult:
    pending = sorted(jobs, key=lambda j: j.arrival)
    n_jobs = len(pending)
    pi = 0                        # next-arrival cursor into `pending`
    st = _SoAState(table_width=capacity + 1)
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    now = 0.0
    peak = 0
    next_resched = 0.0
    is_fixed = strategy.startswith("fixed")
    fixed_key: bytes | None = None
    fixed_target: np.ndarray | None = None
    # Static-event queue: reschedule ticks and restart-freeze expiries, with
    # lazy invalidation (stale entries are discarded at peek time).
    events: list[tuple[float, int, int]] = [(0.0, _EV_RESCHED, -1)]

    def apply_alloc(now: float) -> None:
        nonlocal fixed_key, fixed_target
        n = st.n
        if is_fixed:
            # fixed_k targets depend only on the active-set order, so a
            # pure reschedule tick with an unchanged set can reuse the
            # previous solve verbatim
            key = st.ids[:n].tobytes()
            if key != fixed_key:
                fixed_key = key
                fixed_target = _allocate_soa(strategy, st, capacity, now)
            target = fixed_target
        else:
            target = _allocate_soa(strategy, st, capacity, now)
        changed = np.nonzero(target != st.w[:n])[0]
        if not len(changed):
            return
        st.w[:n] = target
        st.speed_now[changed] = st.tables[changed, target[changed]]
        until = now + RESTART_COST
        for i in changed:
            if target[i] > 0:
                st.frozen[i] = until
                heapq.heappush(events, (until, _EV_UNFREEZE,
                                        int(st.ids[i])))

    while pi < n_jobs or st.n:
        # --- next event time -------------------------------------------
        # discard stale static events, then peek the earliest valid one
        while events:
            t, kind, jid = events[0]
            if kind == _EV_RESCHED:
                if t == next_resched:
                    break
            else:
                i = st.index_of.get(jid)
                if (i is not None and st.w[i] > 0 and st.frozen[i] == t
                        and t > now):
                    break
            heapq.heappop(events)
        # a valid reschedule event always exists; an empty queue means the
        # bookkeeping above lost it and the simulation would stall forever
        assert events, "event queue drained: no reschedule event pending"
        t_min = events[0][0]
        if pi < n_jobs and pending[pi].arrival < t_min:
            t_min = pending[pi].arrival
        # completion estimates are recomputed from (now, remaining) every
        # event on purpose — see module docstring (bit-identical trajectory)
        n = st.n
        if n:
            w = st.w[:n]
            frozen = st.frozen[:n]
            speed = st.speed_now[:n]
            running = np.nonzero((w > 0) & (frozen <= now)
                                 & (speed > 0.0))[0]
            if len(running):
                est = now + st.remaining[:n][running] / speed[running]
                e_min = est.min()
                if e_min < t_min:
                    t_min = e_min
        t_next = now if t_min < now else t_min

        # --- advance progress -------------------------------------------
        if n:
            dt = t_next - np.maximum(frozen, now)
            adv = np.nonzero((w > 0) & (dt > 0.0))[0]
            if len(adv):
                st.remaining[adv] -= dt[adv] * speed[adv]

        now = t_next

        # --- completions -------------------------------------------------
        finished = False
        if n:
            fin = st.remaining[:n] <= 1e-9
            if fin.any():
                finished = True
                for i in np.nonzero(fin)[0]:
                    done[int(st.ids[i])] = now
                st.compact(~fin)

        # --- arrivals ----------------------------------------------------
        arrived = False
        while pi < n_jobs and pending[pi].arrival <= now + 1e-9:
            j = pending[pi]
            pi += 1
            # table to `capacity`, not j.max_w: j.max_w may exceed the
            # cluster (mixed fleets), and a capacity-sized row makes every
            # _SoAState.tables row the same width; the solver never probes
            # past min(j.max_w, capacity) anyway.
            st.add(j, j.speed_table(capacity),
                   now if strategy == "exploratory" else None)
            arrived = True

        if st.n > peak:
            peak = st.n

        # --- reallocation ------------------------------------------------
        if arrived or finished or now + 1e-9 >= next_resched:
            if st.n:
                apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY
            heapq.heappush(events, (next_resched, _EV_RESCHED, -1))

    return SimResult(strategy=strategy, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak)


def _simulate_reference(jobs: list[JobSpec], capacity: int,
                        strategy: str) -> SimResult:
    """The pre-table event loop, kept as the parity/benchmark oracle.

    O(J) candidate rescans, scalar ``JobSpec.speed`` calls throughout, list
    pops for arrivals — the seed implementation's cost profile.  Must stay
    behaviorally identical to ``_simulate_table`` (asserted by tests and
    benchmarks/bench_scheduler.py).
    """
    pending = sorted(jobs, key=lambda j: j.arrival)
    active: list[_Active] = []
    done: dict[int, float] = {}
    arrivals = {j.job_id: j.arrival for j in jobs}
    now = 0.0
    peak = 0
    next_resched = 0.0

    def apply_alloc(now: float):
        target = _allocate(strategy, active, capacity, now)
        for a in active:
            w_new = target.get(a.spec.job_id, 0)
            if w_new != a.w:
                a.w = w_new
                if w_new > 0:
                    a.frozen_until = now + RESTART_COST
        # also freeze explore-phase jobs at segment switches implicitly via
        # reschedule events (RESCHEDULE_EVERY == EXPLORE_SEGMENT).

    while pending or active:
        # --- next event time -------------------------------------------
        # next_resched is always a candidate, so the list is never empty
        t_candidates = [next_resched]
        if pending:
            t_candidates.append(pending[0].arrival)
        for a in active:
            s = a.speed(now)
            if s > 0:
                t_candidates.append(max(now, a.frozen_until)
                                    + a.remaining / s)
            elif a.w > 0 and a.frozen_until > now:
                t_candidates.append(a.frozen_until)
        t_next = max(now, min(t_candidates))

        # --- advance progress -------------------------------------------
        for a in active:
            run_from = max(now, a.frozen_until)
            dt = max(0.0, t_next - run_from)
            a.remaining -= dt * (a.spec.speed(a.w) if a.w > 0 else 0.0)

        now = t_next

        # --- completions -------------------------------------------------
        finished = [a for a in active if a.remaining <= 1e-9]
        for a in finished:
            done[a.spec.job_id] = now
            active.remove(a)

        # --- arrivals ----------------------------------------------------
        arrived = False
        while pending and pending[0].arrival <= now + 1e-9:
            j = pending.pop(0)
            a = _Active(spec=j, remaining=j.epochs)
            if strategy == "exploratory":
                a.explore_started = now
            active.append(a)
            arrived = True

        peak = max(peak, len(active))

        # --- reallocation ------------------------------------------------
        if arrived or finished or now + 1e-9 >= next_resched:
            if active:
                apply_alloc(now)
            next_resched = now + RESCHEDULE_EVERY

    return SimResult(strategy=strategy, completion_times=done,
                     arrival_times=arrivals, peak_concurrency=peak)


def run_table3(seed: int = 0, capacity: int = 64,
               contention: dict[str, tuple[float, int]] | None = None,
               engine: str = "table",
               pattern: str = "poisson") -> dict[str, dict[str, float]]:
    """Reproduce Table 3: avg JCT (hours) per strategy x contention level.

    ``pattern`` selects the arrival/size process from the workload-pattern
    library (``jobs.WORKLOAD_PATTERNS``); the paper's own Table 3 is the
    default ``"poisson"`` trace.
    """
    from repro.core.jobs import make_workload
    contention = contention or {"extreme": (250.0, 206),
                                "moderate": (500.0, 114),
                                "none": (1000.0, 44)}
    strategies = ["precompute", "exploratory", "fixed_8", "fixed_4",
                  "fixed_2", "fixed_1"]
    out: dict[str, dict[str, float]] = {}
    for level, (gap, n_jobs) in contention.items():
        jobs = make_workload(pattern, n_jobs, gap, seed)
        out[level] = {}
        for s in strategies:
            res = simulate(jobs, capacity, s, engine=engine)
            out[level][s] = res.avg_jct_hours
    return out
