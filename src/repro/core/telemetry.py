"""Telemetry: structured event tracing, counters/timers, and exporters.

Zero-overhead-when-off instrumentation for the scheduler simulator.  Three
parts:

1. **Structured event trace** — typed records for job lifecycle (submit,
   admit/delay/reject, alloc-change, freeze/unfreeze, migrate, complete) and
   per-solve decision records, emitted through a pluggable sink.  Sinks:
   in-memory list (:class:`MemorySink`), bounded ring (:class:`RingSink`),
   streaming JSONL (:class:`JSONLSink`, O(1) memory for 100k+-job traces),
   and a streaming Chrome trace-event writer (:class:`ChromeTraceSink`).

2. **Counter/timer registry** — :class:`Registry` hands out
   :class:`Counter`/:class:`Timer` objects resolved once at engine setup.
   The disabled path is a module-level no-op singleton
   (:data:`NULL_RECORDER`), so hot loops pay a single attribute check
   (``rec.on``) when telemetry is off.

3. **Exporters** — Chrome trace-event JSON (one track per node / GPU slot,
   loadable in Perfetto via https://ui.perfetto.dev) and a metrics rollup
   (time-weighted utilization, queue-depth stats, JCT histogram, per-policy
   counter table).

Usage::

    from repro.core import telemetry as tele
    t = tele.Telemetry(sink=tele.MemorySink())
    res = simulate(jobs, capacity, policy, telemetry=t)
    res.telemetry.utilization        # time-weighted busy-GPU fraction
    res.telemetry.counters           # {"solve.calls": ..., "heap.pops": ...}
    res.telemetry.events             # list of event dicts (MemorySink only)

Events are plain dicts with a ``kind`` key; :data:`EVENT_SCHEMAS` defines the
required fields per kind and :func:`validate_event` checks them.  All numeric
payloads are coerced to plain ``int``/``float`` at emission time so every
sink can ``json.dumps`` without numpy-scalar surprises.
"""

from __future__ import annotations

import heapq
import json
import math
from collections import deque
from dataclasses import dataclass, field
from typing import IO, Any

# ---------------------------------------------------------------------------
# Event schemas
# ---------------------------------------------------------------------------

#: Required fields per event kind -> {field_name: type}.  ``float`` accepts
#: ints too (JSON has one number type); extra fields are always allowed.
EVENT_SCHEMAS: dict[str, dict[str, type]] = {
    # One per simulation, first event.
    "run": {
        "t": float,
        "policy": str,
        "capacity": int,
        "n_jobs": int,
        "gpus_per_node": int,
    },
    # Job lifecycle.
    "submit": {"t": float, "job": int, "arrival": float},
    "admit": {"t": float, "job": int},
    "delay": {"t": float, "job": int},
    "reject": {"t": float, "job": int},
    "alloc": {"t": float, "job": int, "old_w": int, "w": int},
    "freeze": {"t": float, "job": int, "until": float},
    "unfreeze": {"t": float, "job": int},
    "migrate": {"t": float, "job": int, "node": int},
    "complete": {"t": float, "job": int, "jct": float},
    # Fault injection (PR 10): a node incident, a gang killed by one
    # (with its checkpoint-age-dependent lost work), and a killed gang
    # re-entering the queue.
    "fault": {"t": float, "node": int, "fault": str},
    "evict": {"t": float, "job": int, "node": int, "lost": float,
              "lost_frac": float},
    "recover": {"t": float, "job": int},
    # Per-solve decision record.
    "solve": {"t": float, "policy": str, "changed": int, "reuse": bool, "n_live": int},
    # One per simulation, last event.
    "end": {"t": float, "n_done": int},
}


def validate_event(ev: dict) -> None:
    """Raise ``ValueError`` if *ev* is not a well-formed telemetry event."""
    kind = ev.get("kind")
    schema = EVENT_SCHEMAS.get(kind)  # type: ignore[arg-type]
    if schema is None:
        raise ValueError(f"unknown event kind: {kind!r}")
    for name, typ in schema.items():
        if name not in ev:
            raise ValueError(f"{kind} event missing field {name!r}: {ev}")
        val = ev[name]
        if typ is float:
            ok = isinstance(val, (int, float)) and not isinstance(val, bool)
        elif typ is int:
            ok = isinstance(val, int) and not isinstance(val, bool)
        elif typ is bool:
            ok = isinstance(val, bool)
        else:
            ok = isinstance(val, typ)
        if not ok:
            raise ValueError(
                f"{kind} event field {name!r} has type {type(val).__name__}, "
                f"expected {typ.__name__}: {ev}"
            )


# ---------------------------------------------------------------------------
# Counters and timers
# ---------------------------------------------------------------------------


class Counter:
    """A named monotonically-increasing integer."""

    __slots__ = ("name", "n")

    def __init__(self, name: str) -> None:
        self.name = name
        self.n = 0

    def inc(self, k: int = 1) -> None:
        self.n += k

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Counter({self.name}={self.n})"


class Timer:
    """Accumulates wall-clock seconds across labelled spans."""

    __slots__ = ("name", "total_s", "count")

    def __init__(self, name: str) -> None:
        self.name = name
        self.total_s = 0.0
        self.count = 0

    def add(self, seconds: float) -> None:
        self.total_s += seconds
        self.count += 1

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Timer({self.name}={self.total_s:.6f}s/{self.count})"


class _NullCounter:
    """No-op counter; shared singleton for the disabled path."""

    __slots__ = ()
    name = "null"
    n = 0

    def inc(self, k: int = 1) -> None:
        pass


class _NullTimer:
    __slots__ = ()
    name = "null"
    total_s = 0.0
    count = 0

    def add(self, seconds: float) -> None:
        pass


NULL_COUNTER = _NullCounter()
NULL_TIMER = _NullTimer()


class Registry:
    """Hands out memoized :class:`Counter`/:class:`Timer` handles by name.

    Resolve handles once at setup (``c = reg.counter("heap.pops")``) and call
    ``c.inc()`` in the hot loop — no dict lookup per increment.
    """

    __slots__ = ("_counters", "_timers")

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._timers: dict[str, Timer] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def timer(self, name: str) -> Timer:
        t = self._timers.get(name)
        if t is None:
            t = self._timers[name] = Timer(name)
        return t

    def counters(self) -> dict[str, int]:
        return {k: v.n for k, v in sorted(self._counters.items())}

    def timers(self) -> dict[str, dict[str, float]]:
        return {
            k: {"total_s": v.total_s, "count": v.count}
            for k, v in sorted(self._timers.items())
        }


class _NullRegistry:
    __slots__ = ()

    def counter(self, name: str) -> _NullCounter:
        return NULL_COUNTER

    def timer(self, name: str) -> _NullTimer:
        return NULL_TIMER

    def counters(self) -> dict[str, int]:
        return {}

    def timers(self) -> dict[str, dict[str, float]]:
        return {}


NULL_REGISTRY = _NullRegistry()


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


class MemorySink:
    """Keeps every event in a plain list (``sink.events``)."""

    __slots__ = ("events",)

    def __init__(self) -> None:
        self.events: list[dict] = []

    def emit(self, ev: dict) -> None:
        self.events.append(ev)

    def close(self) -> None:
        pass


class RingSink:
    """Bounded in-memory sink: keeps only the most recent *maxlen* events."""

    __slots__ = ("_ring",)

    def __init__(self, maxlen: int = 65536) -> None:
        self._ring: deque[dict] = deque(maxlen=maxlen)

    @property
    def events(self) -> list[dict]:
        return list(self._ring)

    def emit(self, ev: dict) -> None:
        self._ring.append(ev)

    def close(self) -> None:
        pass


class JSONLSink:
    """Streams one JSON object per line to *path*; O(1) memory.

    The sink of choice for 100k+-job traces: nothing is buffered beyond the
    underlying file object's write buffer.
    """

    __slots__ = ("path", "_fh")

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh: IO[str] | None = open(path, "w")

    def emit(self, ev: dict) -> None:
        fh = self._fh
        if fh is not None:
            fh.write(json.dumps(ev, separators=(",", ":")))
            fh.write("\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_jsonl(path: str) -> list[dict]:
    """Load a JSONL event file back into a list of event dicts."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class TeeSink:
    """Fans every event out to multiple sinks."""

    __slots__ = ("sinks",)

    def __init__(self, sinks: list) -> None:
        self.sinks = list(sinks)

    def emit(self, ev: dict) -> None:
        for s in self.sinks:
            s.emit(ev)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


class ChromeTraceSink:
    """Streams events straight to Chrome trace-event JSON (Perfetto-loadable).

    Tracks are ``pid`` = node index, ``tid`` = GPU slot within the node.  A
    job holding ``w`` GPUs occupies the ``w`` lowest free slots; every alloc
    change closes the job's open occupancy intervals (``"X"`` complete
    events, ``ts``/``dur`` in microseconds of *simulated* time) and reopens
    them at the new width.  Freeze/unfreeze/migrate show up as instant
    events (``"i"``) on the job's first slot, and a ``busy_gpus`` counter
    track (``"C"``) gives the utilization curve.

    Memory is O(capacity + active jobs), independent of trace length — the
    JSON array is written incrementally and terminated in :meth:`close`.
    """

    __slots__ = ("path", "_fh", "_first", "_free", "_held", "_gpn", "_capacity")

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh: IO[str] | None = open(path, "w")
        self._fh.write('{"displayTimeUnit":"ms","traceEvents":[')
        self._first = True
        self._free: list[int] = []  # min-heap of free GPU slot indices
        self._held: dict[int, list[tuple[int, float]]] = {}  # job -> [(slot, since_t)]
        self._gpn = 1
        self._capacity = 0

    # -- low-level --------------------------------------------------------

    def _write(self, obj: dict) -> None:
        fh = self._fh
        if fh is None:
            return
        if self._first:
            self._first = False
        else:
            fh.write(",")
        fh.write(json.dumps(obj, separators=(",", ":")))

    def _pid_tid(self, slot: int) -> tuple[int, int]:
        return slot // self._gpn, slot % self._gpn

    def _instant(self, t: float, job: int, name: str) -> None:
        spans = self._held.get(job)
        slot = spans[0][0] if spans else 0
        pid, tid = self._pid_tid(slot)
        self._write(
            {"ph": "i", "name": name, "ts": t * 1e6, "pid": pid, "tid": tid, "s": "t",
             "args": {"job": job}}
        )

    def _busy(self, t: float) -> None:
        used = self._capacity - len(self._free)
        self._write(
            {"ph": "C", "name": "busy_gpus", "ts": t * 1e6, "pid": 0, "tid": 0,
             "args": {"busy": used}}
        )

    # -- sink interface ---------------------------------------------------

    def emit(self, ev: dict) -> None:
        if self._fh is None:
            return
        kind = ev["kind"]
        t = ev["t"]
        if kind == "run":
            self._capacity = ev["capacity"]
            self._gpn = max(1, ev.get("gpus_per_node") or 1)
            self._free = list(range(self._capacity))
            heapq.heapify(self._free)
            n_nodes = (self._capacity + self._gpn - 1) // self._gpn
            for node in range(n_nodes):
                self._write(
                    {"ph": "M", "name": "process_name", "ts": 0, "pid": node,
                     "tid": 0, "args": {"name": f"node{node}"}}
                )
                for g in range(self._gpn):
                    if node * self._gpn + g >= self._capacity:
                        break
                    self._write(
                        {"ph": "M", "name": "thread_name", "ts": 0, "pid": node,
                         "tid": g, "args": {"name": f"gpu{g}"}}
                    )
            self._busy(t)
        elif kind == "alloc":
            job = ev["job"]
            w = ev["w"]
            spans = self._held.pop(job, [])
            for slot, since in spans:
                pid, tid = self._pid_tid(slot)
                dur = max(0.0, t - since)
                self._write(
                    {"ph": "X", "name": f"job{job}", "cat": "gang",
                     "ts": since * 1e6, "dur": dur * 1e6, "pid": pid, "tid": tid,
                     "args": {"job": job, "w": ev["old_w"]}}
                )
                heapq.heappush(self._free, slot)
            if w > 0:
                new_spans = []
                for _ in range(min(w, len(self._free))):
                    slot = heapq.heappop(self._free)
                    new_spans.append((slot, t))
                self._held[job] = new_spans
            self._busy(t)
        elif kind == "complete":
            # alloc->0 precedes complete in the engines; this is a fallback.
            job = ev["job"]
            spans = self._held.pop(job, [])
            for slot, since in spans:
                pid, tid = self._pid_tid(slot)
                self._write(
                    {"ph": "X", "name": f"job{job}", "cat": "gang",
                     "ts": since * 1e6, "dur": (t - since) * 1e6,
                     "pid": pid, "tid": tid, "args": {"job": job}}
                )
                heapq.heappush(self._free, slot)
            if spans:
                self._busy(t)
        elif kind == "evict":
            # a node failure killed the gang: close its occupancy spans
            # (same geometry as complete) and free the slots
            job = ev["job"]
            spans = self._held.pop(job, [])
            for slot, since in spans:
                pid, tid = self._pid_tid(slot)
                self._write(
                    {"ph": "X", "name": f"job{job}", "cat": "gang",
                     "ts": since * 1e6, "dur": (t - since) * 1e6,
                     "pid": pid, "tid": tid, "args": {"job": job}}
                )
                heapq.heappush(self._free, slot)
            if spans:
                self._busy(t)
        elif kind == "fault":
            self._write(
                {"ph": "i", "name": ev["fault"], "ts": t * 1e6,
                 "pid": ev["node"], "tid": 0, "s": "p",
                 "args": {"node": ev["node"]}}
            )
        elif kind in ("freeze", "unfreeze", "migrate", "recover"):
            self._instant(t, ev["job"], kind)
        elif kind == "end":
            for job, spans in list(self._held.items()):
                for slot, since in spans:
                    pid, tid = self._pid_tid(slot)
                    self._write(
                        {"ph": "X", "name": f"job{job}", "cat": "gang",
                         "ts": since * 1e6, "dur": (t - since) * 1e6,
                         "pid": pid, "tid": tid, "args": {"job": job}}
                    )
            self._held.clear()
            self._busy(t)
        # submit/admit/delay/reject/solve carry no timeline geometry.

    def close(self) -> None:
        if self._fh is not None:
            self._fh.write("]}")
            self._fh.close()
            self._fh = None


def write_chrome_trace(path: str, events: list[dict]) -> None:
    """Convert a recorded event list to a Chrome trace-event file offline."""
    sink = ChromeTraceSink(path)
    try:
        for ev in events:
            sink.emit(ev)
    finally:
        sink.close()


# ---------------------------------------------------------------------------
# Rollup result
# ---------------------------------------------------------------------------


@dataclass
class TelemetryResult:
    """End-of-run metrics rollup attached to ``SimResult.telemetry``."""

    policy: str
    capacity: int
    n_jobs: int
    makespan: float
    utilization: float | None  # time-weighted mean busy-GPU fraction
    busy_gpu_seconds: float
    queue_peak: int
    queue_mean: float  # time-weighted mean waiting-job count
    n_completed: int
    n_rejected: int
    n_migrations: int
    avg_jct_s: float | None
    # fault injection (PR 10): incidents seen, gangs killed, gpu-seconds
    # wasted on rolled-back progress / restart freezes, and goodput =
    # useful progress-seconds / busy gpu-seconds
    n_faults: int = 0
    n_evictions: int = 0
    lost_gpu_seconds: float = 0.0
    frozen_gpu_seconds: float = 0.0
    goodput: float | None = None
    jct_histogram: dict[str, int] = field(default_factory=dict)  # log2 bins
    counters: dict[str, int] = field(default_factory=dict)
    timers: dict[str, dict[str, float]] = field(default_factory=dict)
    sink: Any = None

    @property
    def events(self) -> list[dict] | None:
        """Recorded events, if the sink keeps them in memory."""
        return getattr(self.sink, "events", None)

    def rollup(self) -> dict:
        """Plain-dict summary (JSON-serializable) for reports/CI artifacts."""
        return {
            "policy": self.policy,
            "capacity": self.capacity,
            "n_jobs": self.n_jobs,
            "makespan": self.makespan,
            "utilization": self.utilization,
            "busy_gpu_seconds": self.busy_gpu_seconds,
            "queue_peak": self.queue_peak,
            "queue_mean": self.queue_mean,
            "n_completed": self.n_completed,
            "n_rejected": self.n_rejected,
            "n_migrations": self.n_migrations,
            "avg_jct_s": self.avg_jct_s,
            "n_faults": self.n_faults,
            "n_evictions": self.n_evictions,
            "lost_gpu_seconds": self.lost_gpu_seconds,
            "frozen_gpu_seconds": self.frozen_gpu_seconds,
            "goodput": self.goodput,
            "jct_histogram": dict(self.jct_histogram),
            "counters": dict(self.counters),
            "timers": dict(self.timers),
        }


def _jct_bin(jct: float) -> str:
    """Log2 histogram bin label for a JCT in seconds: the largest power
    of two <= jct (``frexp`` gives the exponent in O(1))."""
    if jct < 1.0:
        return "<1s"
    return f"{1 << (math.frexp(jct)[1] - 1)}s"


# ---------------------------------------------------------------------------
# Recorder (per-run)
# ---------------------------------------------------------------------------


class Recorder:
    """Per-simulation event recorder + summary accumulator.

    Created by :meth:`Telemetry.recorder` at engine setup.  Engines call the
    ``submit``/``admit``/``alloc``/... methods at the corresponding decision
    points; the recorder maintains time-weighted integrals (busy GPUs,
    queue depth) and streams each event to the sink.

    Bit-consistency note: the busy/queue integrals advance only when
    ``dt > 0``, and the busy count is an integer, so the order of
    same-timestamp events (which differs between the table and reference
    engines) cannot change the float accumulation — both engines produce
    bitwise-equal utilization.
    """

    on = True

    __slots__ = (
        "_sink", "registry", "policy", "capacity", "n_jobs",
        "c_solves", "c_reused", "c_delta", "t_solve",
        "_t", "_busy", "_waiting", "_busy_int", "_wait_int", "_peak_wait",
        "_w", "_sub", "_pend", "_pend_due", "_jct_hist", "_jct_sum",
        "_n_done", "_n_rejected", "_migs", "_closed",
        "_gpu_int", "_gpu_t", "_frz", "_frz_s", "_lost",
        "_n_evict", "_n_faults",
    )

    def __init__(
        self,
        sink,
        registry: Registry,
        policy: str,
        capacity: int,
        n_jobs: int,
        gpus_per_node: int = 0,
        t0: float = 0.0,
    ) -> None:
        self._sink = sink
        self.registry = registry
        self.policy = policy
        self.capacity = int(capacity)
        self.n_jobs = int(n_jobs)
        self.c_solves = registry.counter("solve.calls")
        self.c_reused = registry.counter("solve.reused")
        self.c_delta = registry.counter("solve.changed_rows")
        self.t_solve = registry.timer("solve.wall_s")
        self._t = float(t0)
        self._busy = 0
        self._waiting: set[int] = set()
        self._busy_int = 0.0
        self._wait_int = 0.0
        self._peak_wait = 0
        self._w: dict[int, int] = {}
        self._sub: dict[int, float] = {}
        self._pend: dict[int, float] = {}  # job -> frozen-until (unfreeze due)
        self._pend_due = math.inf          # earliest pending unfreeze (cached)
        self._jct_hist: dict[str, int] = {}
        self._jct_sum = 0.0
        self._n_done = 0
        self._n_rejected = 0
        self._migs = 0
        self._closed = False
        # goodput accounting (PR 10).  Per-job so same-timestamp event
        # ordering differences between the engines cannot reorder the
        # float sums: each job's events are chronological in both
        # engines, and finish() folds the per-job values in sorted-key
        # order — bitwise-equal totals on both engines.
        self._gpu_int: dict[int, float] = {}  # job -> gpu-seconds so far
        self._gpu_t: dict[int, float] = {}    # job -> last integral flush
        self._frz: dict[int, tuple[float, int]] = {}  # job -> (until, w)
        self._frz_s: dict[int, float] = {}    # job -> frozen gpu-seconds
        self._lost: dict[int, float] = {}     # job -> wasted gpu-seconds
        self._n_evict = 0
        self._n_faults = 0
        if sink is not None:
            sink.emit(
                {
                    "kind": "run",
                    "t": float(t0),
                    "policy": policy,
                    "capacity": int(capacity),
                    "n_jobs": int(n_jobs),
                    "gpus_per_node": int(gpus_per_node),
                }
            )

    # -- internals --------------------------------------------------------

    def _tick(self, t: float) -> None:
        dt = t - self._t
        if dt > 0.0:
            self._busy_int += self._busy * dt
            self._wait_int += len(self._waiting) * dt
            self._t = t

    def _enqueue(self, job: int) -> None:
        self._waiting.add(job)
        if len(self._waiting) > self._peak_wait:
            self._peak_wait = len(self._waiting)

    def _emit(self, ev: dict) -> None:
        sink = self._sink
        if sink is not None:
            if self._pend_due <= ev["t"]:
                self._flush_pend(ev["t"])
            sink.emit(ev)

    def _flush_pend(self, t: float) -> None:
        """Emit unfreeze events whose due time has passed, in (until, job)
        order, and refresh the cached earliest-due bound (the bound may
        sit below the true minimum after a re-freeze overwrote an entry —
        that only costs a spurious scan here, never a missed flush)."""
        due = [(u, j) for j, u in self._pend.items() if u <= t]
        if due:
            due.sort()
            sink = self._sink
            for u, j in due:
                del self._pend[j]
                sink.emit({"kind": "unfreeze", "t": float(u), "job": j})
        self._pend_due = min(self._pend.values()) if self._pend else math.inf

    # -- lifecycle events -------------------------------------------------

    def submit(self, t: float, job: int, arrival: float) -> None:
        self._sub[job] = arrival
        if self._sink is not None:
            self._emit({"kind": "submit", "t": t, "job": job,
                        "arrival": arrival})

    def admit(self, t: float, job: int) -> None:
        self._tick(t)
        self._enqueue(job)
        if self._sink is not None:
            self._emit({"kind": "admit", "t": t, "job": job})

    def delay(self, t: float, job: int) -> None:
        self._tick(t)
        self._enqueue(job)
        if self._sink is not None:
            self._emit({"kind": "delay", "t": t, "job": job})

    def reject(self, t: float, job: int) -> None:
        self._tick(t)
        self._waiting.discard(job)
        self._n_rejected += 1
        if self._sink is not None:
            self._emit({"kind": "reject", "t": t, "job": job})

    def alloc(self, t: float, job: int, old_w: int, w: int) -> None:
        self._tick(t)
        self._busy += w - old_w
        if w > 0:
            self._waiting.discard(job)
        else:
            self._enqueue(job)
            if self._pend.pop(job, None) is not None and self._pend:
                self._pend_due = min(self._pend.values())
        # per-job gpu-seconds integral (goodput): close the old-width span
        if old_w > 0:
            self._gpu_int[job] = (self._gpu_int.get(job, 0.0)
                                  + (t - self._gpu_t.get(job, t)) * old_w)
        self._gpu_t[job] = t
        self._w[job] = w
        if self._sink is not None:
            self._emit({"kind": "alloc", "t": t, "job": job, "old_w": old_w,
                        "w": w})

    def freeze(self, t: float, job: int, until: float) -> None:
        # frozen gpu-seconds (goodput) — unconditional, unlike the
        # sink-gated unfreeze bookkeeping below: the span is the union
        # with any still-pending freeze, weighted by the job's current
        # width
        prev = self._frz.get(job)
        add = (until - t) - (max(0.0, prev[0] - t) if prev else 0.0)
        w = self._w.get(job, 0)
        if add > 0.0 and w > 0:
            self._frz_s[job] = self._frz_s.get(job, 0.0) + add * w
        self._frz[job] = (until, w)
        sink = self._sink
        if sink is not None:
            if self._pend_due <= t:
                self._flush_pend(t)
            sink.emit({"kind": "freeze", "t": t, "job": job, "until": until})
            self._pend[job] = until
            if until < self._pend_due:
                self._pend_due = until

    def migrate(self, t: float, job: int, node: int) -> None:
        self._migs += 1
        self._emit(
            {"kind": "migrate", "t": float(t), "job": int(job), "node": int(node)}
        )

    def complete(self, t: float, job: int) -> None:
        self._tick(t)
        w = self._w.pop(job, 0)
        self._busy -= w
        self._waiting.discard(job)
        self._pend.pop(job, None)
        # done for good: the per-job goodput scratch is no longer needed
        # (_lost/_frz_s persist — finish() sums them)
        self._gpu_int.pop(job, None)
        self._gpu_t.pop(job, None)
        self._frz.pop(job, None)
        arrival = self._sub.pop(job, None)
        jct = t - arrival if arrival is not None else 0.0
        self._jct_sum += jct
        b = _jct_bin(jct)
        self._jct_hist[b] = self._jct_hist.get(b, 0) + 1
        self._n_done += 1
        if self._sink is not None:
            self._emit({"kind": "complete", "t": t, "job": job, "jct": jct})

    # -- fault injection (PR 10) ------------------------------------------

    def fault(self, t: float, node: int, fault: str) -> None:
        """A node incident fired (fail/drain/recover/degrade)."""
        self._n_faults += 1
        self._emit({"kind": "fault", "t": float(t), "node": int(node),
                    "fault": fault})

    def evict(self, t: float, job: int, node: int, lost: float,
              lost_frac: float) -> None:
        """A node failure killed ``job``'s gang: release its GPUs, flush
        its gpu-seconds integral, and charge the wasted share (the
        fraction of its progress that rolled back to the last
        checkpoint)."""
        self._tick(t)
        w = self._w.pop(job, 0)
        self._busy -= w
        self._waiting.discard(job)
        if self._pend.pop(job, None) is not None and self._pend:
            self._pend_due = min(self._pend.values())
        if w > 0:
            self._gpu_int[job] = (self._gpu_int.get(job, 0.0)
                                  + (t - self._gpu_t.get(job, t)) * w)
        self._gpu_t.pop(job, None)
        if lost_frac > 0.0:
            self._lost[job] = (self._lost.get(job, 0.0)
                               + self._gpu_int.get(job, 0.0) * lost_frac)
        frz = self._frz.pop(job, None)
        if frz is not None and frz[0] > t:
            # the freeze was cut short by the kill — claw back the tail
            self._frz_s[job] = (self._frz_s.get(job, 0.0)
                                - (frz[0] - t) * frz[1])
        self._n_evict += 1
        self._emit({"kind": "evict", "t": float(t), "job": int(job),
                    "node": int(node), "lost": float(lost),
                    "lost_frac": float(lost_frac)})

    def recover(self, t: float, job: int) -> None:
        """An evicted job re-entered the queue through admission."""
        self._tick(t)
        self._enqueue(job)
        self._emit({"kind": "recover", "t": float(t), "job": int(job)})

    # -- decision records -------------------------------------------------

    def solve_reused(self) -> None:
        # counter-only fast path for reused/empty solves (~80% of solves
        # on steady traces): no event is emitted — a reused solve's whole
        # decision content (delta 0, reuse True) is already captured by
        # the solve.calls/solve.reused counters, and skipping the record
        # keeps the enabled path inside the bench overhead ceiling
        self.c_solves.n += 1
        self.c_reused.n += 1

    def solve(self, t: float, changed: int, reuse: bool, n_live: int) -> None:
        # the hottest recorder method (one call per reallocation event):
        # direct counter bumps, no coercions — engines pass plain scalars
        self.c_solves.n += 1
        if reuse:
            self.c_reused.n += 1
        self.c_delta.n += changed
        sink = self._sink
        if sink is not None:
            if self._pend_due <= t:
                self._flush_pend(t)
            sink.emit({"kind": "solve", "t": t, "policy": self.policy,
                       "changed": changed, "reuse": reuse,
                       "n_live": n_live})

    # -- finalization -----------------------------------------------------

    def finish(self, t: float) -> TelemetryResult:
        """Close out the run: flush, emit ``end``, close the sink, roll up."""
        t = float(t)
        self._tick(t)
        if self._sink is not None:
            self._flush_pend(float("inf"))
            self._sink.emit({"kind": "end", "t": t, "n_done": self._n_done})
            if not self._closed:
                self._sink.close()
                self._closed = True
        denom = self.capacity * t
        util = (self._busy_int / denom) if denom > 0 else None
        # goodput: fold per-job values in sorted-key order so both
        # engines sum bitwise-identically
        lost = sum(self._lost[j] for j in sorted(self._lost))
        frozen = sum(self._frz_s[j] for j in sorted(self._frz_s))
        goodput = (max(0.0, (self._busy_int - lost - frozen)
                       / self._busy_int)
                   if self._busy_int > 0 else None)
        return TelemetryResult(
            policy=self.policy,
            capacity=self.capacity,
            n_jobs=self.n_jobs,
            makespan=t,
            utilization=util,
            busy_gpu_seconds=self._busy_int,
            queue_peak=self._peak_wait,
            queue_mean=(self._wait_int / t) if t > 0 else 0.0,
            n_completed=self._n_done,
            n_rejected=self._n_rejected,
            n_migrations=self._migs,
            avg_jct_s=(self._jct_sum / self._n_done) if self._n_done else None,
            n_faults=self._n_faults,
            n_evictions=self._n_evict,
            lost_gpu_seconds=lost,
            frozen_gpu_seconds=frozen,
            goodput=goodput,
            jct_histogram=dict(sorted(self._jct_hist.items())),
            counters=self.registry.counters(),
            timers=self.registry.timers(),
            sink=self._sink,
        )


class _NullRecorder:
    """Disabled-path recorder: every method is a no-op.

    Hot loops check ``rec.on`` once per block; policy internals see
    ``registry is None`` (via ``ctx.tel``) and skip counting entirely.
    """

    on = False
    registry = None
    __slots__ = ()

    def submit(self, t, job, arrival):
        pass

    def admit(self, t, job):
        pass

    def delay(self, t, job):
        pass

    def reject(self, t, job):
        pass

    def alloc(self, t, job, old_w, w):
        pass

    def freeze(self, t, job, until):
        pass

    def migrate(self, t, job, node):
        pass

    def complete(self, t, job):
        pass

    def fault(self, t, node, fault):
        pass

    def evict(self, t, job, node, lost, lost_frac):
        pass

    def recover(self, t, job):
        pass

    def solve(self, t, changed, reuse, n_live):
        pass

    def solve_reused(self):
        pass

    def finish(self, t):
        return None


NULL_RECORDER = _NullRecorder()


# ---------------------------------------------------------------------------
# Top-level handle
# ---------------------------------------------------------------------------


class Telemetry:
    """Enabled telemetry configuration passed to ``simulate(telemetry=...)``.

    ``sink=None`` collects counters and the metrics rollup without recording
    individual events (cheapest enabled mode).  Pass ``registry`` to share
    one counter registry across several runs; by default each run gets a
    fresh one.
    """

    enabled = True

    __slots__ = ("sink", "registry")

    def __init__(self, sink=None, registry: Registry | None = None) -> None:
        self.sink = sink
        self.registry = registry

    def recorder(
        self, policy: str, capacity: int, n_jobs: int, gpus_per_node: int = 0
    ) -> Recorder:
        reg = self.registry if self.registry is not None else Registry()
        return Recorder(
            self.sink, reg, str(policy), int(capacity), int(n_jobs),
            gpus_per_node=int(gpus_per_node),
        )


class _NullTelemetry:
    enabled = False
    sink = None
    registry = None
    __slots__ = ()

    def recorder(self, policy, capacity, n_jobs, gpus_per_node=0):
        return NULL_RECORDER


NULL = _NullTelemetry()


# ---------------------------------------------------------------------------
# Offline analysis helpers
# ---------------------------------------------------------------------------


def metrics_rollup(events: list[dict]) -> TelemetryResult:
    """Replay a recorded event stream into a fresh metrics rollup.

    Uses the exact same accumulation code as the live :class:`Recorder`, so
    an offline rollup of a JSONL trace matches the live ``SimResult.telemetry``
    float-for-float (counters are not in the event stream and come back
    empty; solve events still rebuild the ``solve.*`` counters).
    """
    rec: Recorder | None = None
    end_t = 0.0
    for ev in events:
        kind = ev["kind"]
        t = ev["t"]
        end_t = max(end_t, t)
        if kind == "run":
            rec = Recorder(
                None, Registry(), ev["policy"], ev["capacity"], ev["n_jobs"],
                gpus_per_node=ev.get("gpus_per_node", 0), t0=t,
            )
        elif rec is None:
            raise ValueError("event stream does not start with a 'run' event")
        elif kind == "submit":
            rec.submit(t, ev["job"], ev["arrival"])
        elif kind == "admit":
            rec.admit(t, ev["job"])
        elif kind == "delay":
            rec.delay(t, ev["job"])
        elif kind == "reject":
            rec.reject(t, ev["job"])
        elif kind == "alloc":
            rec.alloc(t, ev["job"], ev["old_w"], ev["w"])
        elif kind == "freeze":
            rec.freeze(t, ev["job"], ev["until"])
        elif kind == "migrate":
            rec.migrate(t, ev["job"], ev["node"])
        elif kind == "complete":
            rec.complete(t, ev["job"])
        elif kind == "fault":
            rec.fault(t, ev["node"], ev["fault"])
        elif kind == "evict":
            rec.evict(t, ev["job"], ev["node"], ev["lost"], ev["lost_frac"])
        elif kind == "recover":
            rec.recover(t, ev["job"])
        elif kind == "solve":
            rec.solve(t, ev["changed"], ev["reuse"], ev["n_live"])
        elif kind == "end":
            end_t = t
    if rec is None:
        raise ValueError("empty event stream")
    return rec.finish(end_t)


def format_counters(per_policy: dict[str, dict[str, int]]) -> str:
    """Render ``{policy: {counter: value}}`` as an aligned text table."""
    names: list[str] = []
    for ctrs in per_policy.values():
        for k in ctrs:
            if k not in names:
                names.append(k)
    names.sort()
    rows = [["policy", *names]]
    for pol, ctrs in per_policy.items():
        rows.append([pol, *[str(ctrs.get(k, 0)) for k in names]])
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = []
    for i, r in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)
