"""Elastic checkpoint–stop–restart trainer (paper §5–6).

Drives any model exposing ``loss(params, batch)`` through training segments
at varying worker counts w.  Per-worker minibatch m stays fixed (global
batch = m*w, §5), the LR rescales linearly on resize (eq. 7), and LR decay
boundaries stay pinned to *epochs* so they shift in step-space with the
batch size, exactly as the paper describes.  Stop and restart costs are
measured, not assumed — benchmarks/table2_stop_restart.py reports them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.optim.optimizers import Optimizer
from repro.optim.schedule import rescale_lr


@dataclasses.dataclass
class SegmentRecord:
    w: int
    steps: int
    epochs: float
    losses: list           # (global_step, cumulative_epoch, loss)
    seconds: float
    restore_seconds: float
    save_seconds: float


class ElasticTrainer:
    def __init__(self, model, optimizer: Optimizer, data,
                 ckpt: CheckpointStore, *, base_lr_1w: float,
                 m_per_worker: int = 128,
                 decay_epochs: tuple = (100, 150), decay_factor: float = 0.1,
                 dataset_size: int | None = None):
        self.model = model
        self.opt = optimizer
        self.data = data
        self.ckpt = ckpt
        self.base_lr_1w = base_lr_1w
        self.m = m_per_worker
        self.decay_epochs = decay_epochs
        self.decay_factor = decay_factor
        self.dataset = dataset_size or getattr(data, "size", 50_000)

        def train_step(params, opt_state, batch, lr):
            loss, grads = jax.value_and_grad(self.model.loss)(params, batch)
            new_params, new_opt = self.opt.update(grads, opt_state, params,
                                                  lr)
            return loss, new_params, new_opt

        self._step = jax.jit(train_step)

    # ------------------------------------------------------------ state ----
    def fresh_state(self, key=None) -> dict:
        params = self.model.init(key if key is not None
                                 else jax.random.PRNGKey(0))
        return {"params": params, "opt": self.opt.init(params),
                "step": jnp.zeros((), jnp.int32),
                "epoch": jnp.zeros((), jnp.float32)}

    def _lr(self, w: int, epoch: float) -> float:
        # linear scaling (eq. 7 relative to the 1-worker base) + epoch-pinned
        # step decay
        lr = rescale_lr(self.base_lr_1w, w, 1)
        for b in self.decay_epochs:
            if epoch >= b:
                lr *= self.decay_factor
        return lr

    # ---------------------------------------------------------- segments ---
    def train_segment(self, w: int, n_steps: int, *, resume: bool = True,
                      log_every: int = 10) -> SegmentRecord:
        restore_s = 0.0
        if resume and self.ckpt.latest_step() is not None:
            template = self.fresh_state()
            state, meta, restore_s = self.ckpt.restore(template)
        else:
            state = self.fresh_state()

        global_batch = self.m * w
        epochs_per_step = global_batch / self.dataset
        losses = []
        t0 = time.perf_counter()
        step0 = int(state["step"])
        epoch = float(state["epoch"])
        params, opt_state = state["params"], state["opt"]
        for i in range(n_steps):
            gstep = step0 + i
            batch = self.data.batch(gstep, global_batch)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = self._lr(w, epoch)
            loss, params, opt_state = self._step(params, opt_state, batch,
                                                 lr)
            epoch += epochs_per_step
            if i % log_every == 0 or i == n_steps - 1:
                losses.append((gstep, epoch, float(loss)))
        seconds = time.perf_counter() - t0

        state = {"params": params, "opt": opt_state,
                 "step": jnp.asarray(step0 + n_steps, jnp.int32),
                 "epoch": jnp.asarray(epoch, jnp.float32)}
        save_s = self.ckpt.save(step0 + n_steps, state,
                                meta={"w": w, "epoch": epoch})
        return SegmentRecord(w=w, steps=n_steps, epochs=epoch,
                             losses=losses, seconds=seconds,
                             restore_seconds=restore_s, save_seconds=save_s)
