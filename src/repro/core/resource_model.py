"""Resource-to-speed model — paper §3.2, eq. (5).

    f(w) = (theta0 * m/w + theta1 * (w-1) + theta2 * (w-1) * n/w
            + theta3)^{-1}        [epochs/second]

theta >= 0 fitted by NNLS from observed (w, speed) points.  The same f
covers all three all-reduce algorithms (the thetas absorb the different
coefficients of eqs. 2-4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.convergence import nnls


def _features(w: np.ndarray, m: float, n: float) -> np.ndarray:
    w = np.asarray(w, float)
    return np.stack([m / w, (w - 1.0), (w - 1.0) * n / w,
                     np.ones_like(w)], axis=1)


@dataclasses.dataclass(frozen=True)
class ResourceModel:
    theta: np.ndarray          # [4], non-negative
    m: float                   # per-worker minibatch (paper keeps it fixed)
    n: float                   # model/gradient size

    def seconds_per_epoch(self, w) -> np.ndarray:
        w = np.asarray(w, float)
        return _features(w, self.m, self.n) @ self.theta

    def f(self, w) -> np.ndarray:
        """Training speed in epochs/second (eq. 5)."""
        t = self.seconds_per_epoch(w)
        return 1.0 / np.maximum(t, 1e-12)


def fit_resource_model(ws: np.ndarray, speeds: np.ndarray, m: float,
                       n: float) -> ResourceModel:
    """speeds: measured epochs/second at worker counts ws."""
    ws = np.asarray(ws, float)
    speeds = np.asarray(speeds, float)
    y = 1.0 / np.maximum(speeds, 1e-12)        # seconds per epoch
    theta = nnls(_features(ws, m, n), y)
    return ResourceModel(theta=theta, m=m, n=n)


def profile_to_speeds(step_times: dict[int, float], steps_per_epoch_1w: float
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Convert per-step wall times at each w into epochs/sec observations.

    With per-GPU minibatch fixed (paper §5), w workers take
    steps_per_epoch_1w / w steps per epoch.
    """
    ws = np.array(sorted(step_times), float)
    secs_per_epoch = np.array(
        [step_times[int(w)] * steps_per_epoch_1w / w for w in ws])
    return ws, 1.0 / secs_per_epoch
