"""Resource-to-speed model — paper §3.2, eq. (5).

    f(w) = (theta0 * m/w + theta1 * (w-1) + theta2 * (w-1) * n/w
            + theta3)^{-1}        [epochs/second]

theta >= 0 fitted by NNLS from observed (w, speed) points.  The same f
covers all three all-reduce algorithms (the thetas absorb the different
coefficients of eqs. 2-4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.convergence import nnls


def _features(w: np.ndarray, m: float, n: float) -> np.ndarray:
    """Feature matrix [m/w, w-1, (w-1)n/w, 1] for a batch of worker counts.

    Written as four slice assignments into one preallocated array (rather
    than ``np.stack`` of four temporaries): this is the scheduler's hot
    constructor and the temporaries dominated the seed profile.
    """
    w = np.asarray(w, float)
    out = np.empty((w.shape[0], 4))
    out[:, 0] = m / w
    out[:, 1] = w - 1.0
    out[:, 2] = out[:, 1] * n / w
    out[:, 3] = 1.0
    return out


@dataclasses.dataclass(frozen=True)
class ResourceModel:
    theta: np.ndarray          # [4], non-negative
    m: float                   # per-worker minibatch (paper keeps it fixed)
    n: float                   # model/gradient size

    def seconds_per_epoch(self, w) -> np.ndarray:
        w = np.asarray(w, float)
        return _features(w, self.m, self.n) @ self.theta

    def f(self, w) -> np.ndarray:
        """Training speed in epochs/second (eq. 5)."""
        t = self.seconds_per_epoch(w)
        return 1.0 / np.maximum(t, 1e-12)

    def f_pointwise(self, w) -> np.ndarray:
        """Batch f(w) that is bit-identical to per-scalar ``f`` calls.

        The one-shot matmul in ``f`` lets BLAS pick a different kernel for
        tall feature matrices, which perturbs the last ulp relative to the
        (1, 4) @ (4,) matvec the scalar path issues.  Speed *tables* must
        reproduce the scalar path exactly (the simulator promises
        bit-identical completion times), so this evaluates the batch with
        one vectorized ``_features`` call followed by per-row ``np.dot`` —
        the same BLAS trajectory as N scalar calls, minus the N array
        constructions that dominated the seed profile.
        """
        feats = _features(np.asarray(w, float), self.m, self.n)
        t = np.empty(feats.shape[0])
        theta = self.theta
        for i in range(feats.shape[0]):
            t[i] = np.dot(feats[i], theta)
        return 1.0 / np.maximum(t, 1e-12)


def fit_resource_model(ws: np.ndarray, speeds: np.ndarray, m: float,
                       n: float) -> ResourceModel:
    """speeds: measured epochs/second at worker counts ws."""
    ws = np.asarray(ws, float)
    speeds = np.asarray(speeds, float)
    y = 1.0 / np.maximum(speeds, 1e-12)        # seconds per epoch
    theta = nnls(_features(ws, m, n), y)
    return ResourceModel(theta=theta, m=m, n=n)


def profile_to_speeds(step_times: dict[int, float], steps_per_epoch_1w: float
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Convert per-step wall times at each w into epochs/sec observations.

    With per-GPU minibatch fixed (paper §5), w workers take
    steps_per_epoch_1w / w steps per epoch.
    """
    ws = np.array(sorted(step_times), float)
    secs_per_epoch = np.array(
        [step_times[int(w)] * steps_per_epoch_1w / w for w in ws])
    return ws, 1.0 / secs_per_epoch
