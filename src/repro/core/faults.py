"""Fault injection for the §7 simulator: deterministic per-seed churn.

The paper's premise is that ring jobs are cheap to stop and restart
(§5, Table 2) — but through PR 9 the simulator only ever exercised
*voluntary* restarts chosen by the scheduler.  Real clusters lose
machines: GADGET (arXiv 2202.01158) assumes jobs can be preempted and
resumed at any decision epoch, and the systems comparison in arXiv
1909.02061 identifies worker failure as the dominant availability risk
for ring topologies, where one dead peer stalls the whole ring.  This
module supplies the missing involuntary side:

  * :class:`FaultEvent` — one timed incident (``fail`` / ``drain`` /
    ``recover`` / ``degrade``) against one node.
  * :class:`FaultModel` registry (``register_fault_model`` /
    ``get_fault_model`` / ``registered_fault_models``), mirroring the
    policy/placement/admission registries: ``none``, scheduled kills
    (``kill_<t>``), stochastic churn (``churn_<n>``), timed drains
    (``drain_<t>``), permanent stragglers (``stragglers_<k>``), and
    correlated rack outages (``rack_<t>``).  ``schedule()`` is a pure
    function of ``(cluster, seed, horizon)`` — same seed, same schedule,
    bit-identical on both simulator engines.
  * :class:`CheckpointPolicy` — checkpoint-age-dependent lost work.  A
    killed gang loses the progress since its last checkpoint (interval
    in progress-seconds, modeled on ``CheckpointStore``/
    ``ElasticTrainer``: ``save`` every ``interval`` of progress, restore
    rolls back to the last saved step) and pays ``cluster.restart_cost``
    to rejoin the queue.

The engines deliver the schedule through the same calendar-ordered event
loop as arrivals: an empty schedule (``faults=None`` or ``"none"``) is a
structural no-op and existing goldens stay bit-identical.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.scheduler import _int_param, _no_param, _split_spec

__all__ = [
    "FaultEvent", "FaultModel", "CheckpointPolicy",
    "DEFAULT_CHECKPOINT_INTERVAL", "register_fault_model",
    "get_fault_model", "registered_fault_models",
]

# progress-seconds between checkpoints when the cluster does not say
# (ClusterModel.checkpoint_interval): 5 simulated minutes, the same
# order as the explore segments the schedulers already charge for
DEFAULT_CHECKPOINT_INTERVAL = 300.0

FAIL, DRAIN, RECOVER, DEGRADE = "fail", "drain", "recover", "degrade"
_KINDS = (FAIL, DRAIN, RECOVER, DEGRADE)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timed incident against one node.

    ``fail``     — the node dies: every gang with a slot on it is
                   evicted, loses un-checkpointed progress, re-enters
                   the queue through admission.
    ``drain``    — graceful decommission: running gangs stay, no new
                   placements land on the node until it recovers.
    ``recover``  — the node returns to service (clears fail or drain).
    ``degrade``  — straggler: the node runs at ``factor`` of nominal
                   speed; the placement engine routes around it.
    """
    t: float
    kind: str
    node: int
    factor: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {_KINDS}")
        if self.kind == DEGRADE and not 0.0 < self.factor <= 1.0:
            raise ValueError(
                f"degrade factor must be in (0, 1], got {self.factor}")


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Checkpoint-age-dependent lost work, modeled on ``CheckpointStore``
    + ``ElasticTrainer``: the trainer saves every ``interval`` of
    progress, so a crash rolls a job back to its last multiple of
    ``interval`` and the restart pays ``restart_cost`` (the same
    stop-restart pause voluntary reallocations charge, paper §6)."""
    interval: float = DEFAULT_CHECKPOINT_INTERVAL
    restart_cost: float = 10.0

    def __post_init__(self):
        if self.interval <= 0.0:
            raise ValueError(
                f"checkpoint interval must be > 0, got {self.interval}")

    def lost_progress(self, done: float) -> float:
        """Progress-seconds since the last checkpoint: ``done`` minus its
        last multiple of ``interval`` (0 when nothing was done)."""
        if done <= 0.0:
            return 0.0
        return done - self.interval * math.floor(done / self.interval)


class FaultModel:
    """Generates one deterministic fault schedule per (cluster, seed).

    ``schedule`` must be a pure function of its arguments — both
    simulator engines call it independently and require bit-identical
    output — and must return events sorted by time (ties in emit order).
    """

    spec: str = "?"

    def schedule(self, cluster, seed: int,
                 horizon: float) -> tuple[FaultEvent, ...]:
        raise NotImplementedError

    def validate(self, cluster) -> None:
        """Reject model/cluster combinations that cannot work."""

    @staticmethod
    def _sort(events) -> tuple[FaultEvent, ...]:
        return tuple(sorted(events, key=lambda e: e.t))


class NoFaults(FaultModel):
    """Explicit zero-fault model: the full fault machinery threaded
    through with an empty schedule — bit-identical to ``faults=None``
    (the parity gates check exactly that)."""

    spec = "none"

    def schedule(self, cluster, seed, horizon):
        return ()


class ScheduledKill(FaultModel):
    """One scheduled node failure at ``t`` (node picked by seed), the
    node recovers 900 s later.  The minimal reproducible incident."""

    OUTAGE = 900.0

    def __init__(self, t: int):
        if t < 0:
            raise ValueError(f"kill time must be >= 0, got {t}")
        self.t = float(t)
        self.spec = f"kill_{t}"

    def schedule(self, cluster, seed, horizon):
        n = len(cluster.node_specs())
        node = seed % n
        return (FaultEvent(self.t, FAIL, node),
                FaultEvent(self.t + self.OUTAGE, RECOVER, node))


class StochasticChurn(FaultModel):
    """``n`` independent node failures at PCG64-drawn times across the
    horizon, each followed by a ~600 s (exponentially jittered) outage.
    The workhorse churn model: same seed, same incident tape."""

    MEAN_OUTAGE = 600.0

    def __init__(self, n: int):
        if n < 1:
            raise ValueError(f"churn count must be >= 1, got {n}")
        self.n = n
        self.spec = f"churn_{n}"

    def schedule(self, cluster, seed, horizon):
        rng = np.random.default_rng((seed, 0xFA17))
        n_nodes = len(cluster.node_specs())
        span = max(horizon, 1.0)
        events = []
        for _ in range(self.n):
            t = float(rng.uniform(0.0, span))
            node = int(rng.integers(0, n_nodes))
            outage = float(rng.exponential(self.MEAN_OUTAGE)) + 60.0
            events.append(FaultEvent(t, FAIL, node))
            events.append(FaultEvent(t + outage, RECOVER, node))
        return self._sort(events)

    def validate(self, cluster):
        if len(cluster.node_specs()) < 2:
            raise ValueError(
                f"{self.spec!r} on a single-node cluster stalls every "
                f"outage — use >= 2 nodes")


class TimedDrain(FaultModel):
    """Graceful decommission of one node (picked by seed) at ``t``,
    returned to service 900 s later: running gangs finish, the
    placement engine stops routing new gangs there."""

    OUTAGE = 900.0

    def __init__(self, t: int):
        if t < 0:
            raise ValueError(f"drain time must be >= 0, got {t}")
        self.t = float(t)
        self.spec = f"drain_{t}"

    def schedule(self, cluster, seed, horizon):
        n = len(cluster.node_specs())
        node = seed % n
        return (FaultEvent(self.t, DRAIN, node),
                FaultEvent(self.t + self.OUTAGE, RECOVER, node))


class Stragglers(FaultModel):
    """``k`` distinct seed-picked nodes degrade to half speed at t=0 and
    never recover: synchronous rings placed there run at the straggler's
    pace, so placement-aware policies should route around them."""

    FACTOR = 0.5

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"straggler count must be >= 1, got {k}")
        self.k = k
        self.spec = f"stragglers_{k}"

    def schedule(self, cluster, seed, horizon):
        rng = np.random.default_rng((seed, 0x57A6))
        n_nodes = len(cluster.node_specs())
        k = min(self.k, n_nodes)
        nodes = sorted(int(i) for i in
                       rng.choice(n_nodes, size=k, replace=False))
        return tuple(FaultEvent(0.0, DEGRADE, node, self.FACTOR)
                     for node in nodes)

    def validate(self, cluster):
        if self.k >= len(cluster.node_specs()):
            raise ValueError(
                f"{self.spec!r} would degrade every node of a "
                f"{len(cluster.node_specs())}-node cluster — leave at "
                f"least one at full speed")


class RackOutage(FaultModel):
    """Correlated failure: the first half of the fleet (one 'rack') dies
    at ``t`` and recovers 900 s later.  Stresses mass eviction + requeue
    and the capacity-shortfall path."""

    OUTAGE = 900.0

    def __init__(self, t: int):
        if t < 0:
            raise ValueError(f"rack outage time must be >= 0, got {t}")
        self.t = float(t)
        self.spec = f"rack_{t}"

    def schedule(self, cluster, seed, horizon):
        n = len(cluster.node_specs())
        rack = range(n // 2)
        events = [FaultEvent(self.t, FAIL, node) for node in rack]
        events += [FaultEvent(self.t + self.OUTAGE, RECOVER, node)
                   for node in rack]
        return tuple(events)

    def validate(self, cluster):
        if len(cluster.node_specs()) < 2:
            raise ValueError(
                f"{self.spec!r} needs >= 2 nodes (half the fleet must "
                f"leave survivors)")


_FAULT_REGISTRY: dict[str, object] = {}


def register_fault_model(name: str, factory) -> None:
    """Register a fault model; ``factory(param)`` receives the spec
    suffix (``"3"`` for ``"churn_3"``, None for a bare name)."""
    if name in _FAULT_REGISTRY:
        raise ValueError(f"fault model {name!r} already registered")
    _FAULT_REGISTRY[name] = factory


def registered_fault_models() -> tuple[str, ...]:
    return tuple(sorted(_FAULT_REGISTRY))


def get_fault_model(spec) -> FaultModel:
    """Resolve a spec string (or pass through a FaultModel instance)."""
    if isinstance(spec, FaultModel):
        return spec
    if not isinstance(spec, str) or not spec:
        raise ValueError(
            f"fault spec must be a non-empty string or FaultModel, "
            f"got {spec!r}")
    base, param = _split_spec(_FAULT_REGISTRY, spec)
    factory = _FAULT_REGISTRY.get(base)
    if factory is None:
        raise ValueError(
            f"unknown fault model {spec!r}; registered: "
            f"{', '.join(registered_fault_models())}")
    return factory(param)


def _none_factory(param):
    _no_param("none", param, noun="fault model")
    return NoFaults()


register_fault_model("none", _none_factory)
register_fault_model("kill",
                     lambda p: ScheduledKill(_int_param(
                         "kill", p, "kill_1800", noun="fault model")))
register_fault_model("churn",
                     lambda p: StochasticChurn(_int_param(
                         "churn", p, "churn_3", noun="fault model")))
register_fault_model("drain",
                     lambda p: TimedDrain(_int_param(
                         "drain", p, "drain_1800", noun="fault model")))
register_fault_model("stragglers",
                     lambda p: Stragglers(_int_param(
                         "stragglers", p, "stragglers_2",
                         noun="fault model")))
register_fault_model("rack",
                     lambda p: RackOutage(_int_param(
                         "rack", p, "rack_1800", noun="fault model")))
