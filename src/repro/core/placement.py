"""Node-level placement engine for the §7 simulator.

Through PR 3 the cluster was a flat pool: ``ClusterModel`` decided a job
"spans nodes" purely from ``w > gpus_per_node``, ignoring *which* nodes a
gang lands on, fragmentation, and per-node hardware differences.  GADGET
(arXiv 2202.01158) and the multi-tenant contention follow-up (arXiv
2207.07817) both show that placement and link contention reshuffle policy
rankings for ring-all-reduce jobs — this module is what makes the
non-flat scenarios real rather than cosmetic.

Core pieces:

  * :class:`repro.collectives.cost.NodeSpec` (re-exported here) — GPU
    count plus optional per-node :class:`HardwareCoefficients` for
    heterogeneous fleets.
  * :class:`ClusterState` — SoA-friendly per-node free-GPU tracking
    (numpy ``free`` / ``node_gpus`` vectors) plus the live
    :class:`Placement` map, maintained incrementally across events.
  * :class:`PlacementStrategy` registry (``register_placement`` /
    ``get_placement`` / ``registered_placements``), mirroring the policy
    registry: ``packed`` (whole-gang first fit, then index-order fill),
    ``spread`` (max-free balancing), and ``best_fit`` (contention-aware:
    tightest single node that fits, else the fewest nodes — minimizes
    cross-node rings).
  * :class:`Placement` — one job's concrete gang assignment; its
    ``spans`` status derives from the *actual* per-node split under
    fragmentation, replacing the ``w > gpus_per_node`` shortcut.
  * The migration/defragmentation pass (``ClusterModel(defrag=True)``):
    a spanning gang that now fits on one node is consolidated there,
    charging ``restart_cost`` (the engines freeze the moved gang).
  * Admission control (``register_admission`` / ``get_admission``):
    ``admit_all`` (default no-op), ``queue_cap_<n>`` (reject arrivals
    once the active set holds n jobs), ``free_gpus_<k>`` (delay
    admission until k GPUs are free).

Both simulator engines drive one :class:`PlacementEngine` instance each
through the same call sequence (register → admit → apply → release), so
placement trajectories stay bit-identical between the SoA fast path and
the reference oracle.  On a flat cluster the engine is a structural
no-op: a single node means nothing ever spans, every speed factor is
exactly 1.0 (never computed, let alone multiplied approximately), and
completion times are bit-identical to the placement-free paths.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import telemetry as _tele
from repro.collectives.cost import (ClusterModel, HardwareCoefficients,
                                    NodeSpec)
from repro.core.scheduler import _int_param, _no_param, _split_spec

__all__ = [
    "NodeSpec", "Placement", "ClusterState", "PlacementView",
    "PlacementStrategy", "register_placement", "get_placement",
    "registered_placements", "AdmissionRule", "register_admission",
    "get_admission", "registered_admissions", "PlacementEngine",
    "ADMIT", "DELAY", "REJECT",
]


@dataclasses.dataclass(frozen=True)
class Placement:
    """One job's concrete gang assignment: ``((node, gpus), ...)``."""
    job_id: int
    assignment: tuple[tuple[int, int], ...]

    @property
    def w(self) -> int:
        return sum(g for _, g in self.assignment)

    @property
    def spans(self) -> bool:
        """Whether this ring actually crosses node boundaries — derived
        from the assignment, not from ``w > gpus_per_node``."""
        return len(self.assignment) > 1

    @property
    def node_ids(self) -> tuple[int, ...]:
        return tuple(i for i, _ in self.assignment)


class ClusterState:
    """Per-node free-GPU state, updated incrementally across events.

    Fault lifecycle (PR 10): ``fail_node`` kills a node (evicting every
    gang with a slot on it), ``drain_node`` decommissions it gracefully
    (running gangs stay, nothing new lands), ``recover_node`` returns it
    to service, and ``set_speed_mult`` marks it a straggler.  ``avail``
    is what placement may use — identical to ``free`` (the same array,
    not a copy) while every node is healthy, so the zero-fault paths are
    bit-identical to the pre-fault code.
    """

    __slots__ = ("node_gpus", "free", "placements", "ok", "draining",
                 "speed_mult", "_masked", "degraded")

    def __init__(self, nodes: tuple[NodeSpec, ...]):
        self.node_gpus = np.array([n.gpus for n in nodes], np.int64)
        self.free = self.node_gpus.copy()
        self.placements: dict[int, Placement] = {}
        self.ok = np.ones(len(self.node_gpus), bool)
        self.draining = np.zeros(len(self.node_gpus), bool)
        self.speed_mult = np.ones(len(self.node_gpus))
        self._masked = False      # any node failed or draining
        self.degraded = False     # any speed_mult != 1

    @property
    def n_nodes(self) -> int:
        return len(self.node_gpus)

    @property
    def avail(self) -> np.ndarray:
        """Free GPUs placement may actually use: ``free`` itself while
        every node is healthy, else a copy with failed/draining nodes
        masked to zero."""
        if not self._masked:
            return self.free
        out = self.free.copy()
        out[~(self.ok & ~self.draining)] = 0
        return out

    def total_free(self) -> int:
        return int(self.free.sum())

    def largest_free_block(self) -> int:
        return int(self.free.max())

    def total_avail(self) -> int:
        return int(self.avail.sum())

    def largest_avail_block(self) -> int:
        return int(self.avail.max())

    def placed_w(self, job_id: int) -> int:
        pl = self.placements.get(job_id)
        return 0 if pl is None else pl.w

    def assign(self, placement: Placement) -> None:
        assert placement.job_id not in self.placements, placement.job_id
        for node, gpus in placement.assignment:
            assert gpus > 0, placement
            self.free[node] -= gpus
            assert self.free[node] >= 0, (
                f"node {node} oversubscribed placing job "
                f"{placement.job_id}: {placement.assignment}")
        self.placements[placement.job_id] = placement

    def release(self, job_id: int) -> Placement | None:
        pl = self.placements.pop(job_id, None)
        if pl is not None:
            for node, gpus in pl.assignment:
                # a failed node's GPUs are gone until recover_node —
                # releasing a gang that held slots there must not
                # resurrect them (satellite: nodes are not immortal)
                if self.ok[node]:
                    self.free[node] += gpus
        return pl

    # -- fault lifecycle ---------------------------------------------------

    def _refresh_mask(self) -> None:
        self._masked = not bool((self.ok & ~self.draining).all())

    def fail_node(self, node: int) -> list[int]:
        """Kill ``node``: zero its capacity and evict every gang with a
        slot on it.  Returns the victim job ids (sorted)."""
        assert self.ok[node], f"node {node} is already failed"
        self.ok[node] = False
        self.free[node] = 0
        self._refresh_mask()
        victims = sorted(jid for jid, pl in self.placements.items()
                         if node in pl.node_ids)
        for jid in victims:
            self.release(jid)
        return victims

    def drain_node(self, node: int) -> None:
        """Graceful decommission: running gangs stay, nothing new lands
        on the node until ``recover_node``."""
        assert self.ok[node], f"cannot drain failed node {node}"
        self.draining[node] = True
        self._refresh_mask()

    def recover_node(self, node: int) -> None:
        """Return a failed or draining node to service."""
        if not self.ok[node]:
            self.ok[node] = True
            self.free[node] = self.node_gpus[node]
        self.draining[node] = False
        self._refresh_mask()

    def set_speed_mult(self, node: int, factor: float) -> None:
        """Mark ``node`` a straggler running at ``factor`` of nominal."""
        assert 0.0 < factor <= 1.0, factor
        self.speed_mult[node] = factor
        self.degraded = bool((self.speed_mult != 1.0).any())

    def check_invariants(self, capacity: int) -> None:
        """Test hook: no node oversubscribed, granted GPUs conserved
        against the *effective* (surviving) capacity, failed nodes
        empty."""
        assert (self.free >= 0).all(), self.free
        assert (self.free <= self.node_gpus).all(), self.free
        assert (self.free[~self.ok] == 0).all(), self.free
        placed = sum(pl.w for pl in self.placements.values())
        effective = capacity - int(self.node_gpus[~self.ok].sum())
        assert placed + self.total_free() == effective, (
            placed, self.total_free(), effective)
        per_node = np.zeros(self.n_nodes, np.int64)
        for pl in self.placements.values():
            assert pl.w > 0, pl
            for node, gpus in pl.assignment:
                per_node[node] += gpus
        assert (per_node[~self.ok] == 0).all(), per_node
        ok = self.ok
        assert (per_node[ok] + self.free[ok] == self.node_gpus[ok]).all(), \
            per_node


@dataclasses.dataclass(frozen=True)
class PlacementView:
    """Read-only snapshot handed to placement-aware policies via
    ``scheduler.AllocView.placement``: per-node capacities, current free
    GPUs, and the active strategy name.  On fault-capable clusters the
    health vectors are populated (``None`` otherwise) so policies can
    route around dead, draining, or straggling nodes."""
    node_gpus: np.ndarray
    free: np.ndarray
    strategy: str
    ok: np.ndarray | None = None
    draining: np.ndarray | None = None
    speed_mult: np.ndarray | None = None


# --------------------------------------------------------------------------
# Placement strategies.
# --------------------------------------------------------------------------

class PlacementStrategy:
    """Turns a gang size into a concrete per-node assignment.

    ``place`` may assume ``state.total_free() >= w`` (the engines only
    place what the policy's capacity-feasible allocation granted) and
    must return a tuple of ``(node, gpus)`` pairs summing to ``w``
    without oversubscribing any node.
    """

    name: str = "?"

    def place(self, state: ClusterState, w: int) -> tuple[tuple[int, int],
                                                          ...]:
        raise NotImplementedError

    @staticmethod
    def _fill(order, free, w) -> tuple[tuple[int, int], ...]:
        """Take GPUs from nodes in ``order`` until ``w`` are assigned."""
        asg = []
        need = w
        for i in order:
            take = min(need, int(free[i]))
            if take > 0:
                asg.append((int(i), take))
                need -= take
                if need == 0:
                    return tuple(asg)
        raise AssertionError(f"cannot place gang of {w} on free={free}")


class PackedPlacement(PlacementStrategy):
    """First fit: the whole gang on the first node with room; when
    fragmentation forces a split, fill nodes in index order (packing the
    fleet head — on heterogeneous fleets, list the fast nodes first)."""

    name = "packed"

    def place(self, state, w):
        free = state.avail
        for i in range(state.n_nodes):
            if free[i] >= w:
                return ((i, w),)
        return self._fill(range(state.n_nodes), free, w)


class SpreadPlacement(PlacementStrategy):
    """Load balancing: GPUs go to the node with the most free capacity,
    one at a time (ties break toward the lowest index).  Maximizes
    headroom per node — and, deliberately, cross-node rings: the classic
    placement that looks good on utilization dashboards and loses to
    packing once ring all-reduce pays for the fabric (GADGET §5)."""

    name = "spread"

    def place(self, state, w):
        free = state.avail.copy()
        taken = np.zeros(state.n_nodes, np.int64)
        for _ in range(w):
            i = int(np.argmax(free))
            free[i] -= 1
            taken[i] += 1
        return tuple((int(i), int(taken[i]))
                     for i in np.nonzero(taken)[0])


class BestFitPlacement(PlacementStrategy):
    """Contention-aware best fit: the *tightest* single node that fits
    (leaving big blocks intact for later gangs); when the gang must span,
    use the fewest nodes — largest free blocks first — to minimize the
    number of cross-node ring segments."""

    name = "best_fit"

    def place(self, state, w):
        free = state.avail
        best, best_left = -1, None
        for i in range(state.n_nodes):
            left = int(free[i]) - w
            if left >= 0 and (best_left is None or left < best_left):
                best, best_left = i, left
        if best >= 0:
            return ((best, w),)
        # np.argsort(-free, stable) orders by free desc, index asc on ties
        order = np.argsort(-free, kind="stable")
        return self._fill(order, free, w)


_PLACEMENT_REGISTRY: dict[str, type[PlacementStrategy]] = {}


def register_placement(cls: type[PlacementStrategy]) -> None:
    """Register a strategy class under ``cls.name``."""
    if cls.name in _PLACEMENT_REGISTRY:
        raise ValueError(f"placement strategy {cls.name!r} already "
                         f"registered")
    _PLACEMENT_REGISTRY[cls.name] = cls


def registered_placements() -> tuple[str, ...]:
    return tuple(sorted(_PLACEMENT_REGISTRY))


def get_placement(name: str) -> PlacementStrategy:
    cls = _PLACEMENT_REGISTRY.get(name)
    if cls is None:
        raise ValueError(
            f"unknown placement strategy {name!r}; registered: "
            f"{', '.join(registered_placements())}")
    return cls()


register_placement(PackedPlacement)
register_placement(SpreadPlacement)
register_placement(BestFitPlacement)


# --------------------------------------------------------------------------
# Admission control.
# --------------------------------------------------------------------------

ADMIT, DELAY, REJECT = "admit", "delay", "reject"


@dataclasses.dataclass(frozen=True)
class AdmissionView:
    """What an admission rule may look at when a job arrives."""
    n_active: int
    n_delayed: int
    total_free: int
    largest_free_block: int


class AdmissionRule:
    """Decides, per arriving job, ``ADMIT`` / ``DELAY`` (retried at every
    subsequent event) / ``REJECT`` (never runs; recorded in
    ``SimResult.rejected``)."""

    spec: str = "?"

    def decide(self, spec, view: AdmissionView, now: float) -> str:
        raise NotImplementedError

    def validate(self, cluster: ClusterModel) -> None:
        """Reject rule/cluster combinations that can never admit."""


class AdmitAll(AdmissionRule):
    spec = "admit_all"

    def decide(self, spec, view, now):
        return ADMIT


class QueueCap(AdmissionRule):
    """Classic load shedding: reject arrivals once the active set already
    holds ``n`` jobs."""

    def __init__(self, n: int):
        self.n = n
        self.spec = f"queue_cap_{n}"

    def decide(self, spec, view, now):
        return REJECT if view.n_active >= self.n else ADMIT


class FreeGpus(AdmissionRule):
    """Backpressure: delay admission until at least ``k`` GPUs are free,
    so a new gang never lands on a fully saturated cluster."""

    def __init__(self, k: int):
        self.k = k
        self.spec = f"free_gpus_{k}"

    def decide(self, spec, view, now):
        return ADMIT if view.total_free >= self.k else DELAY

    def validate(self, cluster):
        if self.k > cluster.capacity:
            raise ValueError(
                f"{self.spec!r} can never admit on a "
                f"{cluster.capacity}-GPU cluster (k must be <= capacity)")


_ADMISSION_REGISTRY: dict[str, object] = {}


def register_admission(name: str, factory) -> None:
    """Register an admission rule; ``factory(param)`` receives the spec
    suffix (``"64"`` for ``"queue_cap_64"``, None for a bare name)."""
    if name in _ADMISSION_REGISTRY:
        raise ValueError(f"admission rule {name!r} already registered")
    _ADMISSION_REGISTRY[name] = factory


def registered_admissions() -> tuple[str, ...]:
    return tuple(sorted(_ADMISSION_REGISTRY))


def get_admission(spec: str) -> AdmissionRule:
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"admission spec must be a non-empty string, "
                         f"got {spec!r}")
    base, param = _split_spec(_ADMISSION_REGISTRY, spec)
    factory = _ADMISSION_REGISTRY.get(base)
    if factory is None:
        raise ValueError(
            f"unknown admission rule {spec!r}; registered: "
            f"{', '.join(registered_admissions())}")
    return factory(param)


def _admit_all_factory(param):
    _no_param("admit_all", param, noun="admission rule")
    return AdmitAll()


register_admission("admit_all", _admit_all_factory)
register_admission("queue_cap",
                   lambda p: QueueCap(_int_param("queue_cap", p,
                                                 "queue_cap_64",
                                                 noun="admission rule")))
register_admission("free_gpus",
                   lambda p: FreeGpus(_int_param("free_gpus", p,
                                                 "free_gpus_8",
                                                 noun="admission rule")))


# --------------------------------------------------------------------------
# The engine.
# --------------------------------------------------------------------------

class PlacementEngine:
    """Owns the node-level state for one simulation run.

    Both simulator engines drive it identically: ``register`` at arrival,
    ``admit`` for admission control, ``apply`` at every reallocation
    event (returns which rows need their speed refreshed, with the new
    placement factors and spanning flags), ``release`` at completion.
    """

    def __init__(self, cluster: ClusterModel):
        self.cluster = cluster
        self.nodes = cluster.node_specs()
        self.state = ClusterState(self.nodes)
        self.strategy = get_placement(cluster.placement)
        self.admission = get_admission(cluster.admission)
        self.spec_of: dict[int, object] = {}
        self.migrations = 0
        # telemetry recorder (set by the engines when telemetry is on);
        # the no-op singleton keeps the defrag pass unconditional
        self.rec = _tele.NULL_RECORDER
        # (sorted node ids, spans) -> effective HardwareCoefficients
        self._hw_cache: dict = {}
        self._uniform_hw = all(n.hw is None or n.hw == cluster.hw
                               for n in self.nodes)
        # fault-capable run: apply() clamps grants to surviving capacity
        # (gated so the zero-fault path is byte-identical)
        self.faulty = cluster.faults is not None
        # jobs whose speed factor must refresh at the next apply() even
        # though their gang did not change (straggler degradation)
        self.dirty: set[int] = set()

    # -- arrivals ----------------------------------------------------------

    def register(self, spec) -> None:
        self.spec_of[spec.job_id] = spec

    def admit(self, spec, n_active: int, n_delayed: int, now: float) -> str:
        # avail == free (same values) while every node is healthy
        view = AdmissionView(n_active=n_active, n_delayed=n_delayed,
                             total_free=self.state.total_avail(),
                             largest_free_block=(
                                 self.state.largest_avail_block()))
        verdict = self.admission.decide(spec, view, now)
        assert verdict in (ADMIT, DELAY, REJECT), verdict
        return verdict

    # -- fault delivery ----------------------------------------------------

    def fail(self, node: int) -> list[int]:
        """Node death: returns the evicted job ids (sorted).  Killing an
        already-dead node is a no-op — stochastic churn can draw the
        same node twice with overlapping outages."""
        if not self.state.ok[node]:
            return []
        return self.state.fail_node(node)

    def drain(self, node: int) -> None:
        if self.state.ok[node] and not self.state.draining[node]:
            self.state.drain_node(node)

    def recover(self, node: int) -> None:
        self.state.recover_node(node)

    def degrade(self, node: int, factor: float) -> None:
        """Straggler: the node runs at ``factor``; gangs already placed
        there get their speed refreshed at the next apply()."""
        self.state.set_speed_mult(node, factor)
        for jid, pl in self.state.placements.items():
            if node in pl.node_ids:
                self.dirty.add(jid)

    # -- policy-facing view ------------------------------------------------

    def view(self) -> PlacementView:
        # all arrays are copies: a policy mutating its snapshot must not
        # corrupt the engine's live bookkeeping
        st = self.state
        return PlacementView(node_gpus=st.node_gpus.copy(),
                             free=st.free.copy(),
                             strategy=self.strategy.name,
                             ok=st.ok.copy() if self.faulty else None,
                             draining=(st.draining.copy()
                                       if self.faulty else None),
                             speed_mult=(st.speed_mult.copy()
                                         if self.faulty else None))

    # -- the per-event placement pass --------------------------------------

    def apply(self, ids, target, changed, now: float = 0.0):
        """Re-place changed gangs, run the defrag pass, and report.

        ``ids``/``target`` are the active set (ids and new worker counts,
        active-list order); ``changed`` are the positions whose count
        differs from the currently placed gang.  Returns ``(upd,
        factors, spans)``: the positions whose speed must be refreshed
        (changed plus migrated), each with its new placement factor and
        actual spanning flag.  Factors multiply the *flat* speed table —
        exactly 1.0 for a non-spanning gang on default-hardware nodes.
        ``now`` is the simulated time, only used to timestamp telemetry
        migrate events.
        """
        st = self.state
        for pos in changed:
            st.release(int(ids[pos]))
        for pos in changed:
            w = int(target[pos])
            if w > 0:
                jid = int(ids[pos])
                if self.faulty:
                    # a fault-blind policy may grant more than the
                    # surviving nodes hold — clamp (mutating ``target``
                    # so the engines record the placed count)
                    room = int(st.avail.sum())
                    if w > room:
                        w = room
                        target[pos] = w
                        if w == 0:
                            continue
                st.assign(Placement(jid, self.strategy.place(st, w)))
        moved = self._defrag(ids, now) if self.cluster.defrag else ()
        dirty: list[int] = []
        if self.dirty:
            live = {int(ids[p]): p for p in range(len(ids))}
            dirty = [live[j] for j in self.dirty if j in live]
            self.dirty.clear()
        upd = sorted(set(changed) | set(moved) | set(dirty))
        factors = np.ones(len(upd))
        spans = np.zeros(len(upd), bool)
        for k, pos in enumerate(upd):
            f, sp = self._job_factor(int(ids[pos]))
            factors[k] = f
            spans[k] = sp
        return np.asarray(upd, np.int64), factors, spans

    def release(self, job_id: int) -> None:
        self.state.release(job_id)

    def _defrag(self, ids, now: float = 0.0) -> list[int]:
        """Single consolidation pass in active-list order: a spanning
        gang that now fits on one node moves to the *fastest* such node
        (its own GPUs there count as available; ties broken tightest
        fit, then lowest index), and only when the move strictly beats
        the current placement factor — on a heterogeneous fleet a slow
        node may free up that would make the gang slower than its
        spanning ring, and paying ``restart_cost`` for that is never
        worth it.  Later gangs see the space earlier moves freed."""
        st = self.state
        moved = []
        for pos in range(len(ids)):
            jid = int(ids[pos])
            pl = st.placements.get(jid)
            if pl is None or not pl.spans:
                continue
            w = pl.w
            own = dict(pl.assignment)
            cur_f, _ = self._job_factor(jid)
            best, best_f, best_left = -1, cur_f, None
            av = st.avail      # == st.free (live array) while healthy
            masked = av is not st.free
            for i in range(st.n_nodes):
                if masked and not (st.ok[i] and not st.draining[i]):
                    continue   # never consolidate onto a dead/draining node
                left = int(av[i]) + own.get(i, 0) - w
                if left < 0:
                    continue
                f = self._assignment_factor(jid, (i,), False, w)
                if f > best_f or (f == best_f and best >= 0
                                  and left < best_left):
                    best, best_f, best_left = i, f, left
            if best >= 0:
                st.release(jid)
                st.assign(Placement(jid, ((best, w),)))
                self.migrations += 1
                moved.append(pos)
                if self.rec.on:
                    self.rec.migrate(now, jid, best)
        return moved

    # -- placement-dependent speed -----------------------------------------

    def _job_factor(self, job_id: int) -> tuple[float, bool]:
        """(speed multiplier over the flat table, actual spanning flag)
        for the job's current placement."""
        pl = self.state.placements.get(job_id)
        if pl is None:
            return 1.0, False
        return (self._assignment_factor(job_id, pl.node_ids, pl.spans,
                                        pl.w), pl.spans)

    def _assignment_factor(self, job_id: int, node_ids: tuple[int, ...],
                           spans: bool, w: int) -> float:
        """Speed multiplier a ``w``-gang on ``node_ids`` would run at."""
        # synchronous training runs at the slowest straggler's pace;
        # kept outside _gang_hw/_hw_cache (which do not key on mult)
        mult = 1.0
        if self.state.degraded:
            mult = float(min(self.state.speed_mult[i] for i in node_ids))
        if not spans and self._uniform_hw:
            return mult
        hw_eff = self._gang_hw(node_ids, spans)
        if hw_eff == self.cluster.hw:
            return mult
        tab = self.spec_of[job_id].placement_factor(self.cluster, hw_eff)
        return mult * float(tab[w])

    def _gang_hw(self, node_ids: tuple[int, ...],
                 spans: bool) -> HardwareCoefficients:
        """Effective coefficients a gang on ``node_ids`` sees: the
        slowest involved node per constant (synchronous training runs at
        the straggler's pace), with the cross-node β when the ring spans."""
        key = (tuple(sorted(node_ids)), spans)   # order-independent set
        hw = self._hw_cache.get(key)
        if hw is None:
            cl = self.cluster
            hws = [self.nodes[i].hw or cl.hw for i in node_ids]
            if spans and all(h == cl.hw for h in hws):
                hw = cl.inter_hw()      # same object legacy tables use
            else:
                alpha = max(h.alpha for h in hws)
                gamma = max(h.gamma for h in hws)
                beta = (cl.inter_node_beta if spans
                        else max(h.beta for h in hws))
                hw = HardwareCoefficients(
                    alpha=alpha, beta=beta, gamma=gamma,
                    name=f"{cl.hw.name}+placed")
            self._hw_cache[key] = hw
        return hw
