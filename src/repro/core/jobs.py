"""Job model for the scheduler: each job is a DL training run whose speed
f(w) comes from either the analytic cost models (eqs. 2-4, algorithm-aware
and therefore *bumpy* across the power-of-two boundary — the effect the
doubling heuristic exploits) or a fitted ResourceModel (eq. 5).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.collectives import cost as cost_lib

# Interned speed/factor rows, shared across JobSpec instances whose
# speed-determining fields agree (see ``JobSpec._table_key``).  Distinct
# parameter sets are few (hardware presets x cluster shapes), so the
# cache stays tiny even across 100k-job traces; entries are read-only
# arrays whose object identity doubles as the simulator's row id.
_TABLE_INTERN: dict[tuple, np.ndarray] = {}


@dataclasses.dataclass
class JobSpec:
    """Static description of a training job."""
    job_id: int
    arrival: float                 # seconds
    epochs: float                  # epochs to convergence (Q at start)
    dataset: int = 50_000          # examples/epoch (CIFAR-10)
    m: int = 128                   # per-worker minibatch (paper §5)
    n_bytes: float = 6.9e6         # gradient size (ResNet-110 ~1.7M params f32)
    T_fwd: float = 108e-3 / 128    # per-example forward (Table 1)
    T_back: float = 236.5e-3 / 128  # per-example backward (Table 1)
    # Calibrated against Table 1 measured T_total (402.5 -> 470.2 ms for
    # w = 1 -> 8): fixed framework overhead plus per-worker overhead from
    # backprop/all-reduce overlap contention.
    T_const: float = 48e-3
    T_per_worker: float = 9.7e-3
    hw: cost_lib.HardwareCoefficients = cost_lib.INFINIBAND_100G
    max_w: int = 8                 # paper's single-node cap
    # "table2": f(w) fitted (eq. 5, NNLS) to the paper's measured Table-2
    # job totals — the faithful basis for the §7 simulation.  "analytic":
    # eqs. (2)-(4) from first principles (bumpy across power-of-two w —
    # used to demonstrate the doubling-vs-greedy trap at LLM-scale n).
    speed_mode: str = "table2"

    def step_time(self, w: int) -> float:
        """Per-minibatch wall time at w workers (algorithm-aware)."""
        return (cost_lib.step_time(self.m, self.T_fwd, self.T_back, w,
                                   self.n_bytes, self.hw)
                + self.T_const + self.T_per_worker * w)

    def speed(self, w: int) -> float:
        """f(w): epochs per second at w workers (0 workers -> 0)."""
        if w <= 0:
            return 0.0
        if self.speed_mode == "table2":
            base = float(_table2_model().f(np.array([w]))[0])
            # non-power-of-two w pays the binary-blocks penalty (eq. 4 vs 3)
            if w & (w - 1):
                t_dh = cost_lib.t_dh(self.m, self.T_fwd, self.T_back,
                                     w, self.n_bytes, self.hw)
                t_bb = cost_lib.t_bb(self.m, self.T_fwd, self.T_back,
                                     w, self.n_bytes, self.hw)
                base *= t_dh / t_bb
            return base
        steps_per_epoch = self.dataset / (self.m * w)
        return 1.0 / (steps_per_epoch * self.step_time(w))

    def time_for(self, epochs: float, w: int) -> float:
        s = self.speed(w)
        return math.inf if s <= 0 else epochs / s

    def speed_table(self,
                    cluster: "cost_lib.ClusterModel | int | None" = None
                    ) -> np.ndarray:
        """Cached ``speed[w]`` for w = 0..max index (index 0 is 0.0).

        ``cluster`` is either a :class:`ClusterModel` (max index =
        ``cluster.capacity``, with the cross-node β penalty applied to
        every node-spanning w — see ``_cluster_speed_table``), a plain int
        max index (the flat homogeneous table, exactly the paper's model),
        or ``None`` for ``self.max_w``.  A flat ClusterModel delegates to
        the int path, so it is bit-identical to the integer form by
        construction.

        The int path is bit-identical to ``[self.speed(w) for w in
        range(max_w + 1)]`` but built with one vectorized pass instead of
        one feature-matrix construction per call — the fix for the seed
        profile where 169k scalar ``speed`` calls burned >90% of
        simulation wall time.  Returned arrays are cached, read-only and
        *interned*: jobs whose speed-determining fields agree (everything
        but ``job_id``/``arrival``/``epochs``/``max_w``) share one array
        object, so a 10k-job fleet of identical hardware builds one table
        row instead of 10k and the simulator can collapse its per-job
        table matrix to the handful of distinct rows (keyed by object
        identity).  Don't mutate JobSpec fields after the first call.
        """
        if isinstance(cluster, cost_lib.ClusterModel):
            if cluster.gpus_per_node is None or cluster.placement is not None:
                # flat fabric — or a placement engine, which owns the
                # spanning decision per *actual* assignment and applies
                # it as a factor over the flat table (``placement_factor``)
                return self.speed_table(cluster.capacity)
            return self._cluster_speed_table(cluster)
        max_w = self.max_w if cluster is None else int(cluster)
        cache = self.__dict__.setdefault("_speed_tables", {})
        tab = cache.get(max_w)
        if tab is None:
            key = self._table_key(max_w)
            tab = _TABLE_INTERN.get(key)
            if tab is None:
                tab = self._build_speed_table(max_w)
                tab.flags.writeable = False
                _TABLE_INTERN[key] = tab
            cache[max_w] = tab
        return tab

    def _table_key(self, tail) -> tuple:
        """Interning key: every field the speed curve depends on (NOT
        job_id/arrival/epochs/max_w — tables are built to a caller-chosen
        width, so per-job caps never enter the values) plus the
        width/cluster tail."""
        return (self.speed_mode, self.dataset, self.m, self.n_bytes,
                self.T_fwd, self.T_back, self.T_const, self.T_per_worker,
                self.hw, tail)

    def _cluster_speed_table(self, cluster) -> np.ndarray:
        """Topology-aware speed table: flat base speeds, with rows whose
        ring spans nodes (w > gpus_per_node) scaled by the analytic
        intra/inter step-time ratio (same m/T_fwd/T_back/n_bytes, β
        swapped for ``cluster.inter_node_beta``).  Cached per cluster —
        ClusterModel is frozen/hashable — and interned across jobs like
        the flat rows."""
        cache = self.__dict__.setdefault("_speed_tables", {})
        tab = cache.get(cluster)
        if tab is not None:
            return tab
        key = self._table_key(cluster)
        tab = _TABLE_INTERN.get(key)
        if tab is None:
            tab = self.speed_table(cluster.capacity).copy()
            ws = np.arange(len(tab), dtype=float)
            span = np.asarray(cluster.spans_nodes(np.arange(len(tab))))
            span[0] = False
            if span.any():
                t_intra = cost_lib.step_time_table(
                    self.m, self.T_fwd, self.T_back, ws[span], self.n_bytes,
                    cluster.hw)
                t_inter = cost_lib.step_time_table(
                    self.m, self.T_fwd, self.T_back, ws[span], self.n_bytes,
                    cluster.inter_hw())
                tab[span] *= t_intra / t_inter
            tab.flags.writeable = False
            _TABLE_INTERN[key] = tab
        cache[cluster] = tab
        return tab

    def placement_factor(self, cluster, hw_eff) -> np.ndarray:
        """Speed multiplier table for a gang running on effective
        coefficients ``hw_eff`` instead of the cluster baseline:
        ``factor[w] = t_base(w) / t_eff(w)`` (the analytic step-time
        ratio — the same scaling ``_cluster_speed_table`` bakes into
        spanning rows, here applied per *actual* placement by the
        placement engine).  Cached per (capacity, hw_eff); index 0 is
        1.0 (unused)."""
        cache = self.__dict__.setdefault("_factor_tables", {})
        # the baseline hw is part of the key: equal-capacity clusters with
        # different baseline coefficients must not share factor tables
        key = (cluster.capacity, cluster.hw, hw_eff)
        tab = cache.get(key)
        if tab is not None:
            return tab
        # factor curves depend only on the communication fields, so they
        # intern across jobs like the speed tables
        gkey = (self.m, self.T_fwd, self.T_back, self.n_bytes) + key
        tab = _TABLE_INTERN.get(gkey)
        if tab is None:
            ws = np.arange(1, cluster.capacity + 1, dtype=float)
            t_base = cost_lib.step_time_table(self.m, self.T_fwd,
                                              self.T_back, ws, self.n_bytes,
                                              cluster.hw)
            t_eff = cost_lib.step_time_table(self.m, self.T_fwd,
                                             self.T_back, ws, self.n_bytes,
                                             hw_eff)
            tab = np.ones(cluster.capacity + 1)
            tab[1:] = t_base / t_eff
            tab.flags.writeable = False
            _TABLE_INTERN[gkey] = tab
        cache[key] = tab
        return tab

    def _build_speed_table(self, max_w: int) -> np.ndarray:
        tab = np.zeros(max_w + 1)
        if max_w < 1:
            return tab
        ws = np.arange(1, max_w + 1, dtype=float)
        if self.speed_mode == "table2":
            base = _table2_model().f_pointwise(ws)
            wi = np.arange(1, max_w + 1)
            nonp2 = (wi & (wi - 1)) != 0
            if nonp2.any():
                # binary-blocks penalty (eq. 4 vs 3) applied as a vector
                wnp = ws[nonp2]
                t_dh = cost_lib.t_dh(self.m, self.T_fwd, self.T_back,
                                     wnp, self.n_bytes, self.hw)
                t_bb = cost_lib.t_bb(self.m, self.T_fwd, self.T_back,
                                     wnp, self.n_bytes, self.hw)
                base[nonp2] = base[nonp2] * (t_dh / t_bb)
            tab[1:] = base
        else:
            step = (cost_lib.step_time_table(self.m, self.T_fwd, self.T_back,
                                             ws, self.n_bytes, self.hw)
                    + self.T_const + self.T_per_worker * ws)
            steps_per_epoch = self.dataset / (self.m * ws)
            tab[1:] = 1.0 / (steps_per_epoch * step)
        return tab


# Paper Table 2 baselines: (w, epochs, minutes) for ResNet-110/CIFAR-10.
TABLE2_RUNS = [(1, 160, 368.0), (2, 170, 232.0), (4, 160, 126.0),
               (8, 170, 84.0)]
_TABLE2_CACHE = None


def _table2_model():
    """ResourceModel (eq. 5) NNLS-fitted to the paper's Table 2 runs."""
    global _TABLE2_CACHE
    if _TABLE2_CACHE is None:
        from repro.core.resource_model import fit_resource_model
        ws = np.array([r[0] for r in TABLE2_RUNS], float)
        speeds = np.array([r[1] / (r[2] * 60.0) for r in TABLE2_RUNS])
        _TABLE2_CACHE = fit_resource_model(ws, speeds, m=128, n=6.9e6)
    return _TABLE2_CACHE


def make_speed_table(job: JobSpec, max_w: int) -> np.ndarray:
    """speed[w] for w = 0..max_w (index 0 is 0.0).  Writable copy of the
    cached ``JobSpec.speed_table``."""
    return job.speed_table(max_w).copy()


def synthetic_workload(n_jobs: int, mean_interarrival: float, seed: int,
                       epoch_lo: float = 120, epoch_hi: float = 200
                       ) -> list[JobSpec]:
    """Poisson arrivals (exponential gaps), epochs ~ U[lo, hi] — §7 setup."""
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for j in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        jobs.append(JobSpec(job_id=j, arrival=t,
                            epochs=float(rng.uniform(epoch_lo, epoch_hi))))
    return jobs


# --------------------------------------------------------------------------
# Workload-pattern library.
#
# The paper's headline claim ("more than halves average job time on *some
# workload patterns*") was only ever exercised on the Poisson trace above;
# these generators cover the arrival/size regimes the large-trace
# ring-all-reduce scheduler papers (GADGET, arXiv 2202.01158;
# prediction-assisted online scheduling, arXiv 2501.05563) evaluate on.
# Every generator is deterministic per (n_jobs, mean_interarrival, seed),
# emits jobs in nondecreasing arrival order with job_id = list index, and
# keeps the long-run arrival rate at 1/mean_interarrival so JCT numbers
# are comparable across patterns at a given contention level.
# --------------------------------------------------------------------------

def bursty_workload(n_jobs: int, mean_interarrival: float, seed: int,
                    burst_mean: float = 5.0, epoch_lo: float = 120,
                    epoch_hi: float = 200) -> list[JobSpec]:
    """Batched arrivals: geometric-size bursts land at a single instant.

    Burst sizes ~ Geometric(1/burst_mean); gaps between bursts are
    exponential with mean ``burst_mean * mean_interarrival`` so the
    long-run job rate matches the Poisson trace.  Models gang submissions
    (hyperparameter sweeps, queued overnight batches) that slam the
    scheduler with simultaneous admissions.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs: list[JobSpec] = []
    while len(jobs) < n_jobs:
        t += float(rng.exponential(burst_mean * mean_interarrival))
        size = min(int(rng.geometric(1.0 / burst_mean)), n_jobs - len(jobs))
        for _ in range(size):
            jobs.append(JobSpec(job_id=len(jobs), arrival=t,
                                epochs=float(rng.uniform(epoch_lo,
                                                         epoch_hi))))
    return jobs


def diurnal_workload(n_jobs: int, mean_interarrival: float, seed: int,
                     period: float = 86_400.0, amplitude: float = 0.75,
                     epoch_lo: float = 120, epoch_hi: float = 200
                     ) -> list[JobSpec]:
    """Time-varying arrival rate: λ(t) = (1 + A·sin(2πt/period)) / gap.

    Non-homogeneous Poisson process via Lewis-Shedler thinning — a daily
    submission cycle (busy daytime, quiet nights) whose peak rate is
    (1+A)× the trough's (1-A)×.
    """
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    rng = np.random.default_rng(seed)
    lam_max = (1.0 + amplitude) / mean_interarrival
    t = 0.0
    jobs: list[JobSpec] = []
    while len(jobs) < n_jobs:
        t += float(rng.exponential(1.0 / lam_max))
        lam_t = (1.0 + amplitude * math.sin(2.0 * math.pi * t / period)
                 ) / mean_interarrival
        if float(rng.uniform()) * lam_max <= lam_t:
            jobs.append(JobSpec(job_id=len(jobs), arrival=t,
                                epochs=float(rng.uniform(epoch_lo,
                                                         epoch_hi))))
    return jobs


def heavy_tailed_workload(n_jobs: int, mean_interarrival: float, seed: int,
                          alpha: float = 1.8, epoch_scale: float = 60.0,
                          epoch_cap: float = 2_000.0) -> list[JobSpec]:
    """Poisson arrivals with Pareto(α) job sizes: mostly short jobs plus a
    heavy tail of long-running ones.

    epochs = epoch_scale · Pareto(α) (classic Pareto, x_m = 1, so epochs
    >= epoch_scale), clipped at epoch_cap to keep traces finite; α = 1.8
    gives mean ≈ 2.25 · epoch_scale with infinite variance — the regime
    where a few stragglers dominate average JCT and dynamic reallocation
    has the most room to help.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for j in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        epochs = epoch_scale * (1.0 + float(rng.pareto(alpha)))
        jobs.append(JobSpec(job_id=j, arrival=t,
                            epochs=min(epochs, epoch_cap)))
    return jobs


def mixed_maxw_workload(n_jobs: int, mean_interarrival: float, seed: int,
                        maxw_choices: tuple[int, ...] = (2, 4, 8, 16),
                        epoch_lo: float = 120, epoch_hi: float = 200
                        ) -> list[JobSpec]:
    """Heterogeneous fleet: per-job scale-out cap drawn from maxw_choices.

    Models clusters mixing small single-GPU-class jobs with large
    multi-node ones — the doubling heuristic's gains shift when some jobs
    cannot absorb more workers.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for j in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        jobs.append(JobSpec(job_id=j, arrival=t,
                            epochs=float(rng.uniform(epoch_lo, epoch_hi)),
                            max_w=int(maxw_choices[int(
                                rng.integers(len(maxw_choices)))])))
    return jobs


WORKLOAD_PATTERNS = {
    "poisson": synthetic_workload,
    "bursty": bursty_workload,
    "diurnal": diurnal_workload,
    "heavy_tailed": heavy_tailed_workload,
    "mixed_maxw": mixed_maxw_workload,
}


def make_workload(pattern: str, n_jobs: int, mean_interarrival: float,
                  seed: int, **kwargs) -> list[JobSpec]:
    """Generate ``n_jobs`` jobs from a named workload pattern."""
    try:
        gen = WORKLOAD_PATTERNS[pattern]
    except KeyError:
        raise ValueError(f"unknown workload pattern {pattern!r}; "
                         f"choose from {sorted(WORKLOAD_PATTERNS)}") from None
    return gen(n_jobs, mean_interarrival, seed, **kwargs)
