"""Job model for the scheduler: each job is a DL training run whose speed
f(w) comes from either the analytic cost models (eqs. 2-4, algorithm-aware
and therefore *bumpy* across the power-of-two boundary — the effect the
doubling heuristic exploits) or a fitted ResourceModel (eq. 5).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.collectives import cost as cost_lib


@dataclasses.dataclass
class JobSpec:
    """Static description of a training job."""
    job_id: int
    arrival: float                 # seconds
    epochs: float                  # epochs to convergence (Q at start)
    dataset: int = 50_000          # examples/epoch (CIFAR-10)
    m: int = 128                   # per-worker minibatch (paper §5)
    n_bytes: float = 6.9e6         # gradient size (ResNet-110 ~1.7M params f32)
    T_fwd: float = 108e-3 / 128    # per-example forward (Table 1)
    T_back: float = 236.5e-3 / 128  # per-example backward (Table 1)
    # Calibrated against Table 1 measured T_total (402.5 -> 470.2 ms for
    # w = 1 -> 8): fixed framework overhead plus per-worker overhead from
    # backprop/all-reduce overlap contention.
    T_const: float = 48e-3
    T_per_worker: float = 9.7e-3
    hw: cost_lib.HardwareCoefficients = cost_lib.INFINIBAND_100G
    max_w: int = 8                 # paper's single-node cap
    # "table2": f(w) fitted (eq. 5, NNLS) to the paper's measured Table-2
    # job totals — the faithful basis for the §7 simulation.  "analytic":
    # eqs. (2)-(4) from first principles (bumpy across power-of-two w —
    # used to demonstrate the doubling-vs-greedy trap at LLM-scale n).
    speed_mode: str = "table2"

    def step_time(self, w: int) -> float:
        """Per-minibatch wall time at w workers (algorithm-aware)."""
        return (cost_lib.step_time(self.m, self.T_fwd, self.T_back, w,
                                   self.n_bytes, self.hw)
                + self.T_const + self.T_per_worker * w)

    def speed(self, w: int) -> float:
        """f(w): epochs per second at w workers (0 workers -> 0)."""
        if w <= 0:
            return 0.0
        if self.speed_mode == "table2":
            base = float(_table2_model().f(np.array([w]))[0])
            # non-power-of-two w pays the binary-blocks penalty (eq. 4 vs 3)
            if w & (w - 1):
                t_dh = cost_lib.t_dh(self.m, self.T_fwd, self.T_back,
                                     w, self.n_bytes, self.hw)
                t_bb = cost_lib.t_bb(self.m, self.T_fwd, self.T_back,
                                     w, self.n_bytes, self.hw)
                base *= t_dh / t_bb
            return base
        steps_per_epoch = self.dataset / (self.m * w)
        return 1.0 / (steps_per_epoch * self.step_time(w))

    def time_for(self, epochs: float, w: int) -> float:
        s = self.speed(w)
        return math.inf if s <= 0 else epochs / s

    def speed_table(self, max_w: int | None = None) -> np.ndarray:
        """Cached ``speed[w]`` for w = 0..max_w (index 0 is 0.0).

        Bit-identical to ``[self.speed(w) for w in range(max_w + 1)]`` but
        built with one vectorized pass instead of one feature-matrix
        construction per call — this is the fix for the seed profile where
        169k scalar ``speed`` calls burned >90% of simulation wall time.
        The returned array is cached and read-only; don't mutate JobSpec
        fields after the first call.
        """
        max_w = self.max_w if max_w is None else int(max_w)
        cache = self.__dict__.setdefault("_speed_tables", {})
        tab = cache.get(max_w)
        if tab is None:
            tab = self._build_speed_table(max_w)
            tab.flags.writeable = False
            cache[max_w] = tab
        return tab

    def _build_speed_table(self, max_w: int) -> np.ndarray:
        tab = np.zeros(max_w + 1)
        if max_w < 1:
            return tab
        ws = np.arange(1, max_w + 1, dtype=float)
        if self.speed_mode == "table2":
            base = _table2_model().f_pointwise(ws)
            wi = np.arange(1, max_w + 1)
            nonp2 = (wi & (wi - 1)) != 0
            if nonp2.any():
                # binary-blocks penalty (eq. 4 vs 3) applied as a vector
                wnp = ws[nonp2]
                t_dh = cost_lib.t_dh(self.m, self.T_fwd, self.T_back,
                                     wnp, self.n_bytes, self.hw)
                t_bb = cost_lib.t_bb(self.m, self.T_fwd, self.T_back,
                                     wnp, self.n_bytes, self.hw)
                base[nonp2] = base[nonp2] * (t_dh / t_bb)
            tab[1:] = base
        else:
            step = (cost_lib.step_time_table(self.m, self.T_fwd, self.T_back,
                                             ws, self.n_bytes, self.hw)
                    + self.T_const + self.T_per_worker * ws)
            steps_per_epoch = self.dataset / (self.m * ws)
            tab[1:] = 1.0 / (steps_per_epoch * step)
        return tab


# Paper Table 2 baselines: (w, epochs, minutes) for ResNet-110/CIFAR-10.
TABLE2_RUNS = [(1, 160, 368.0), (2, 170, 232.0), (4, 160, 126.0),
               (8, 170, 84.0)]
_TABLE2_CACHE = None


def _table2_model():
    """ResourceModel (eq. 5) NNLS-fitted to the paper's Table 2 runs."""
    global _TABLE2_CACHE
    if _TABLE2_CACHE is None:
        from repro.core.resource_model import fit_resource_model
        ws = np.array([r[0] for r in TABLE2_RUNS], float)
        speeds = np.array([r[1] / (r[2] * 60.0) for r in TABLE2_RUNS])
        _TABLE2_CACHE = fit_resource_model(ws, speeds, m=128, n=6.9e6)
    return _TABLE2_CACHE


def make_speed_table(job: JobSpec, max_w: int) -> np.ndarray:
    """speed[w] for w = 0..max_w (index 0 is 0.0).  Writable copy of the
    cached ``JobSpec.speed_table``."""
    return job.speed_table(max_w).copy()


def synthetic_workload(n_jobs: int, mean_interarrival: float, seed: int,
                       epoch_lo: float = 120, epoch_hi: float = 200
                       ) -> list[JobSpec]:
    """Poisson arrivals (exponential gaps), epochs ~ U[lo, hi] — §7 setup."""
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for j in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        jobs.append(JobSpec(job_id=j, arrival=t,
                            epochs=float(rng.uniform(epoch_lo, epoch_hi))))
    return jobs
