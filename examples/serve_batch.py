"""Batched serving demo: prefill a batch of prompts, decode new tokens
through the KV-cache/SSM-state serve path for three different families.

  PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_smoke_config
from repro.launch.serve import serve


def main():
    for arch in ("qwen2.5-3b", "mamba2-780m", "jamba-v0.1-52b"):
        cfg = get_smoke_config(arch)
        print(f"=== {arch} (reduced) ===")
        gen, dt = serve(cfg, batch=4, prompt_len=16, new_tokens=8)
        print(f"  first row: {gen[0].tolist()}")


if __name__ == "__main__":
    main()
