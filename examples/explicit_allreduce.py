"""Paper-faithful gradient exchange: data-parallel training where gradients
move through OUR ring / doubling-halving all-reduce (lax.ppermute inside
shard_map) instead of GSPMD's implicit psum — Horovod semantics, TPU-native.

Runs on 8 emulated host devices; the env flag MUST precede the jax import.

  PYTHONPATH=src python examples/explicit_allreduce.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys
import time

sys.path.insert(0, "src")

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.data.synthetic import TokenStream
from repro.engine.steps import make_train_step, init_train_state
from repro.launch.mesh import make_data_mesh
from repro.models.registry import build_model
from repro.optim.optimizers import sgd


def main():
    n_dev = jax.device_count()
    print(f"devices: {n_dev}")
    cfg = get_smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    opt = sgd()
    mesh = make_data_mesh(n_dev)
    data = TokenStream(cfg.vocab_size, 64, seed=0)

    for mode in ("psum", "ring", "doubling_halving"):
        step_fn = make_train_step(
            model, opt, grad_exchange=None if mode == "psum" else mode)
        if mode == "psum":
            # implicit GSPMD reduction still needs a mean over the axis —
            # run the same shard_map shell with lax.psum inside.
            step_fn = make_train_step(model, opt, grad_exchange="psum")
        jitted = jax.jit(jax.shard_map(
            step_fn, mesh=mesh,
            in_specs=(P(), {"tokens": P("data"), "labels": P("data")}, P()),
            out_specs=(P(), P()), check_vma=False))
        state = init_train_state(model, opt)
        losses = []
        t0 = time.perf_counter()
        for i in range(10):
            batch = {k: jnp.asarray(v)
                     for k, v in data.batch(i, 8 * n_dev).items()}
            state, loss = jitted(state, batch, jnp.float32(0.05))
            losses.append(float(loss))
        print(f"{mode:18s} losses {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({time.perf_counter()-t0:.1f}s)")


if __name__ == "__main__":
    main()
