"""Scheduler simulation (paper §7 / Table 3): 64-GPU cluster, the paper's
six strategies plus the registry extensions (SRTF, GADGET-style utility
greedy) — the paper's Poisson trace against its published numbers, then
the same sweep across the workload-pattern library (bursty / diurnal /
heavy-tailed / mixed max_w fleets) at moderate contention, and the
multi-node contention scenario where the flat-cluster ranking reshuffles.
Each sweep block ends with the per-policy decision-counter table the
telemetry layer collects alongside the trajectories.

  PYTHONPATH=src python examples/scheduler_sim.py

With any of the output flags the script instead runs one instrumented
trace and writes the requested artifacts, then exits:

  PYTHONPATH=src python examples/scheduler_sim.py \\
      --trace-out trace.json          # Chrome trace-event JSON (Perfetto)
      --events-out events.jsonl       # raw structured event stream
      --rollup-out rollup.json        # metrics rollup (JSON)
      --trace-jobs 200                # trace size (default 200)
      --trace-policy precompute       # policy to trace
"""
import argparse
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")     # for the benchmarks package (repo root)

from repro.core.simulator import TABLE3_STRATEGIES, run_table3

PAPER = {
    "extreme": [7.63, 20.42, 22.76, 12.90, 11.49, 10.10],
    "moderate": [2.63, 2.92, 6.20, 3.50, 4.58, 6.32],
    "none": [1.40, 1.47, 1.40, 2.21, 3.78, 6.37],
}
STRATS = list(TABLE3_STRATEGIES)


def _header():
    print(f"{'':12s}" + "".join(f"{s:>15s}" for s in STRATS))


def main():
    ours = run_table3(seed=0)
    _header()
    for level in ("extreme", "moderate", "none"):
        row = ours[level]
        print(f"{level:12s}" + "".join(f"{row[s]:15.2f}" for s in STRATS)
              + "   (ours, h)")
        # registry extensions have no paper column — pad with em dashes
        pad = "".join(f"{'—':>15s}" for _ in
                      range(len(STRATS) - len(PAPER[level])))
        print(f"{'':12s}" + "".join(f"{v:15.2f}" for v in PAPER[level])
              + pad + "   (paper, h)")
    m = ours["moderate"]
    print(f"\nmoderate contention: precompute is "
          f"{m['fixed_8']/m['precompute']:.2f}x faster than fixed-8 "
          f"(paper: 2.36x); 'none' ties fixed-8 exactly as in the paper.")

    # same sweep the benchmark publishes (single source for the
    # moderate-contention point)
    from benchmarks.table3_scheduler_sim import run_multinode, run_patterns

    print(f"\nper-pattern sweep (moderate contention, avg JCT h):")
    _header()
    for pattern, row in run_patterns(seed=0).items():
        print(f"{pattern:12s}" + "".join(f"{row[s]:15.2f}" for s in STRATS))
    print("\n(the abstract's 'more than halves average job time on some "
          "workload patterns'\n holds wherever precompute is <= half the "
          "worst fixed-w column)")

    print("\nmulti-node cluster (8-GPU nodes, 10x slower cross-node links, "
          "5% contention\npenalty per concurrent ring — "
          "benchmarks.table3_scheduler_sim.MULTINODE):")
    _header()
    mrow = run_multinode(seed=0)
    print(f"{'moderate':12s}" + "".join(f"{mrow[s]:15.2f}" for s in STRATS))
    best = min(mrow, key=mrow.get)
    print(f"\nonce placement and contention enter the model the flat-cluster "
          f"ranking is not\na given (GADGET's point): best here is "
          f"{best} at {mrow[best]:.2f} h vs precompute's "
          f"{mrow['precompute']:.2f} h.")

    # placement engine (PR 4): gangs get concrete per-node assignments;
    # spanning and contention derive from the actual split under
    # fragmentation, migration/defrag consolidates spanning gangs, and
    # placement-aware pack_* strategies stop paying for the fabric
    from benchmarks.table3_scheduler_sim import (PLACEMENT_STRATEGIES,
                                                 run_placement)

    print("\nplacement-engine scenarios (mixed max_w fleet, moderate "
          "contention, avg JCT h;\nfragmented 8x8-GPU cluster on 1 Gbit/s-"
          "class cross-node links + heterogeneous\nfleet with 4 older "
          "quarter-speed nodes):")
    print(f"{'':16s}" + "".join(f"{s:>17s}" for s in PLACEMENT_STRATEGIES))
    rows = run_placement(seed=0)
    for name, row in rows.items():
        print(f"{name:16s}" + "".join(f"{row[s]:17.2f}"
                                      for s in PLACEMENT_STRATEGIES))
    frag = rows["frag_best_fit"]
    print(f"\nplacement-aware vs blind on the fragmented cluster: pack_srtf "
          f"{frag['srtf'] / frag['pack_srtf']:.1f}x faster than srtf, "
          f"pack_precompute "
          f"{frag['precompute'] / frag['pack_precompute']:.2f}x faster "
          f"than precompute;\ndefrag alone is worth "
          f"{rows['frag_no_defrag']['precompute'] / frag['precompute']:.2f}x "
          f"on precompute, and spread placement costs "
          # apples to apples: both sides defrag-free (frag_spread vs
          # frag_no_defrag), so the ratio isolates the strategy choice
          f"{rows['frag_spread']['precompute'] / rows['frag_no_defrag']['precompute']:.1f}x"
          f" over best-fit (defrag off on both).")

    # fault injection (PR 10): deterministic churn on the fragmented
    # cluster — JCT alone hides the cost of killed gangs, so each policy
    # is also scored on goodput (useful progress-seconds per busy
    # GPU-second, net of rolled-back work and restart freezes)
    from benchmarks.table3_scheduler_sim import CHURN_STRATEGIES, run_churn

    print("\nchurn scenarios (fragmented cluster + deterministic fault "
          "injection, mixed\nmax_w fleet; per cell: avg JCT h / goodput / "
          "evictions):")
    print(f"{'':14s}" + "".join(f"{s:>22s}" for s in CHURN_STRATEGIES))
    churn = run_churn(seed=0)
    for name, row in churn.items():
        cells = "".join(
            f"{row[s]['jct_h']:9.2f}/{row[s]['goodput']:.3f}/"
            f"{int(row[s]['evictions']):3d}" for s in CHURN_STRATEGIES)
        print(f"{name:14s}" + cells)
    c6 = churn["churn_6"]
    print(f"\nfailure-aware vs blind under churn: recovery_aware holds "
          f"{c6['recovery_aware']['goodput']:.3f} goodput vs srtf's "
          f"{c6['srtf']['goodput']:.3f} while finishing "
          f"{c6['srtf']['jct_h'] / c6['recovery_aware']['jct_h']:.1f}x "
          f"faster — blind srtf spans node boundaries, so one node death "
          f"kills whole rings.")

    # per-policy decision counters on the paper's moderate trace: how
    # much work each policy's solver actually did to produce its column
    from repro.core import telemetry as tele
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate

    trace = make_workload("poisson", 114, 500.0, 0)
    per_policy = {}
    for strat in STRATS:
        res = simulate(trace, 64, strat, telemetry=tele.Telemetry())
        per_policy[strat] = res.telemetry.counters
    print("\ndecision counters (moderate-contention paper trace, telemetry "
          "on — trajectory\nbit-identical to the sweep above):")
    print(tele.format_counters(per_policy))


def run_trace(args) -> None:
    """One instrumented trace -> the requested artifact files."""
    from repro.core import telemetry as tele
    from repro.core.jobs import make_workload
    from repro.core.simulator import simulate
    from repro.collectives.cost import ClusterModel

    sinks = []
    if args.trace_out:
        sinks.append(tele.ChromeTraceSink(args.trace_out))
    if args.events_out:
        sinks.append(tele.JSONLSink(args.events_out))
    sink = (None if not sinks
            else sinks[0] if len(sinks) == 1 else tele.TeeSink(sinks))
    # multi-node cluster so the Chrome trace gets one process per node
    cluster = ClusterModel(capacity=64, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e8)
    jobs = make_workload("poisson", args.trace_jobs, 500.0, 0)
    res = simulate(jobs, cluster=cluster, strategy=args.trace_policy,
                   telemetry=tele.Telemetry(sink=sink))
    roll = res.telemetry.rollup()
    if args.rollup_out:
        with open(args.rollup_out, "w") as fh:
            json.dump(roll, fh, indent=2, sort_keys=True)
    print(f"{args.trace_policy}: {len(jobs)} jobs, makespan "
          f"{roll['makespan']:.0f} s, utilization "
          f"{roll['utilization']:.3f}, avg JCT {roll['avg_jct_s']:.0f} s")
    for flag, path in (("trace", args.trace_out),
                       ("events", args.events_out),
                       ("rollup", args.rollup_out)):
        if path:
            print(f"  {flag:7s} -> {path}")


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--events-out", default=None,
                    help="write the raw structured event stream as JSONL")
    ap.add_argument("--rollup-out", default=None,
                    help="write the metrics rollup as JSON")
    ap.add_argument("--trace-jobs", type=int, default=200,
                    help="jobs in the instrumented trace (default 200)")
    ap.add_argument("--trace-policy", default="precompute",
                    help="policy to trace (default precompute)")
    return ap.parse_args(argv)


if __name__ == "__main__":
    _args = _parse_args()
    if _args.trace_out or _args.events_out or _args.rollup_out:
        run_trace(_args)
    else:
        main()
