"""Scheduler simulation (paper §7 / Table 3): 64-GPU cluster, the paper's
six strategies plus the registry extensions (SRTF, GADGET-style utility
greedy) — the paper's Poisson trace against its published numbers, then
the same sweep across the workload-pattern library (bursty / diurnal /
heavy-tailed / mixed max_w fleets) at moderate contention, and the
multi-node contention scenario where the flat-cluster ranking reshuffles.

  PYTHONPATH=src python examples/scheduler_sim.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")     # for the benchmarks package (repo root)

from repro.core.simulator import TABLE3_STRATEGIES, run_table3

PAPER = {
    "extreme": [7.63, 20.42, 22.76, 12.90, 11.49, 10.10],
    "moderate": [2.63, 2.92, 6.20, 3.50, 4.58, 6.32],
    "none": [1.40, 1.47, 1.40, 2.21, 3.78, 6.37],
}
STRATS = list(TABLE3_STRATEGIES)


def _header():
    print(f"{'':12s}" + "".join(f"{s:>15s}" for s in STRATS))


def main():
    ours = run_table3(seed=0)
    _header()
    for level in ("extreme", "moderate", "none"):
        row = ours[level]
        print(f"{level:12s}" + "".join(f"{row[s]:15.2f}" for s in STRATS)
              + "   (ours, h)")
        # registry extensions have no paper column — pad with em dashes
        pad = "".join(f"{'—':>15s}" for _ in
                      range(len(STRATS) - len(PAPER[level])))
        print(f"{'':12s}" + "".join(f"{v:15.2f}" for v in PAPER[level])
              + pad + "   (paper, h)")
    m = ours["moderate"]
    print(f"\nmoderate contention: precompute is "
          f"{m['fixed_8']/m['precompute']:.2f}x faster than fixed-8 "
          f"(paper: 2.36x); 'none' ties fixed-8 exactly as in the paper.")

    # same sweep the benchmark publishes (single source for the
    # moderate-contention point)
    from benchmarks.table3_scheduler_sim import run_multinode, run_patterns

    print(f"\nper-pattern sweep (moderate contention, avg JCT h):")
    _header()
    for pattern, row in run_patterns(seed=0).items():
        print(f"{pattern:12s}" + "".join(f"{row[s]:15.2f}" for s in STRATS))
    print("\n(the abstract's 'more than halves average job time on some "
          "workload patterns'\n holds wherever precompute is <= half the "
          "worst fixed-w column)")

    print("\nmulti-node cluster (8-GPU nodes, 10x slower cross-node links, "
          "5% contention\npenalty per concurrent ring — "
          "benchmarks.table3_scheduler_sim.MULTINODE):")
    _header()
    mrow = run_multinode(seed=0)
    print(f"{'moderate':12s}" + "".join(f"{mrow[s]:15.2f}" for s in STRATS))
    best = min(mrow, key=mrow.get)
    print(f"\nonce placement and contention enter the model the flat-cluster "
          f"ranking is not\na given (GADGET's point): best here is "
          f"{best} at {mrow[best]:.2f} h vs precompute's "
          f"{mrow['precompute']:.2f} h.")

    # placement engine (PR 4): gangs get concrete per-node assignments;
    # spanning and contention derive from the actual split under
    # fragmentation, migration/defrag consolidates spanning gangs, and
    # placement-aware pack_* strategies stop paying for the fabric
    from benchmarks.table3_scheduler_sim import (PLACEMENT_STRATEGIES,
                                                 run_placement)

    print("\nplacement-engine scenarios (mixed max_w fleet, moderate "
          "contention, avg JCT h;\nfragmented 8x8-GPU cluster on 1 Gbit/s-"
          "class cross-node links + heterogeneous\nfleet with 4 older "
          "quarter-speed nodes):")
    print(f"{'':16s}" + "".join(f"{s:>17s}" for s in PLACEMENT_STRATEGIES))
    rows = run_placement(seed=0)
    for name, row in rows.items():
        print(f"{name:16s}" + "".join(f"{row[s]:17.2f}"
                                      for s in PLACEMENT_STRATEGIES))
    frag = rows["frag_best_fit"]
    print(f"\nplacement-aware vs blind on the fragmented cluster: pack_srtf "
          f"{frag['srtf'] / frag['pack_srtf']:.1f}x faster than srtf, "
          f"pack_precompute "
          f"{frag['precompute'] / frag['pack_precompute']:.2f}x faster "
          f"than precompute;\ndefrag alone is worth "
          f"{rows['frag_no_defrag']['precompute'] / frag['precompute']:.2f}x "
          f"on precompute, and spread placement costs "
          # apples to apples: both sides defrag-free (frag_spread vs
          # frag_no_defrag), so the ratio isolates the strategy choice
          f"{rows['frag_spread']['precompute'] / rows['frag_no_defrag']['precompute']:.1f}x"
          f" over best-fit (defrag off on both).")


if __name__ == "__main__":
    main()
