"""Scheduler simulation (paper §7 / Table 3): 64-GPU cluster, Poisson
arrivals, six strategies.

  PYTHONPATH=src python examples/scheduler_sim.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.simulator import run_table3

PAPER = {
    "extreme": [7.63, 20.42, 22.76, 12.90, 11.49, 10.10],
    "moderate": [2.63, 2.92, 6.20, 3.50, 4.58, 6.32],
    "none": [1.40, 1.47, 1.40, 2.21, 3.78, 6.37],
}
STRATS = ["precompute", "exploratory", "fixed_8", "fixed_4", "fixed_2",
          "fixed_1"]


def main():
    ours = run_table3(seed=0)
    print(f"{'':12s}" + "".join(f"{s:>13s}" for s in STRATS))
    for level in ("extreme", "moderate", "none"):
        row = ours[level]
        print(f"{level:12s}" + "".join(f"{row[s]:13.2f}" for s in STRATS)
              + "   (ours, h)")
        print(f"{'':12s}" + "".join(f"{v:13.2f}" for v in PAPER[level])
              + "   (paper, h)")
    m = ours["moderate"]
    print(f"\nmoderate contention: precompute is "
          f"{m['fixed_8']/m['precompute']:.2f}x faster than fixed-8 "
          f"(paper: 2.36x); 'none' ties fixed-8 exactly as in the paper.")


if __name__ == "__main__":
    main()
