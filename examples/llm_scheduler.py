"""Capstone: the paper's scheduler driving THIS framework's workloads.

Builds per-architecture speed models f(w) from the dry-run roofline records
(compute+memory terms scale ~1/w with more chips; the collective term is
~flat in the relevant range, playing the role of the paper's (w-1)n/w
term), then allocates a 512-chip fleet across training jobs for the
assigned architectures with the doubling heuristic vs Optimus +1-greedy.

  PYTHONPATH=src python examples/llm_scheduler.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.core import scheduler as S

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")
BASE_CHIPS = 256  # the mesh the roofline terms were measured on


def load_train_records():
    out = {}
    for fn in glob.glob(os.path.join(DRYRUN, "*train_4k__16x16__baseline*")):
        r = json.load(open(fn))
        roof = r["roofline"]
        out[r["arch"]] = {
            "serial_s": (roof["compute_s"] + roof["memory_s"]) * BASE_CHIPS,
            "coll_s": roof["collective_s"],
        }
    return out


def speed_fn(rec):
    """epochs/sec up to a constant: 1 / step_time(w)."""
    def f(w):
        if w <= 0:
            return 0.0
        return 1.0 / (rec["serial_s"] / w + rec["coll_s"])
    return f


def main():
    recs = load_train_records()
    if not recs:
        print("run the dry-run sweep first"); return
    jobs = []
    for i, (arch, rec) in enumerate(sorted(recs.items())):
        # remaining epochs Q: pretend each job needs 100 "epochs" of its
        # own step time — Q only weights the marginal-gain comparison.
        jobs.append((i, 100.0, speed_fn(rec)))
    archs = [a for a, _ in sorted(recs.items())]

    C = 512
    doubling = S.doubling_heuristic(jobs, C)
    greedy = S.optimus_greedy(jobs, C)
    t_d = S.total_time(jobs, doubling)
    t_g = S.total_time(jobs, greedy)

    print(f"{'arch':22s} {'doubling':>9s} {'greedy':>7s}   (chips)")
    for i, a in enumerate(archs):
        print(f"{a:22s} {doubling[i]:9d} {greedy[i]:7d}")
    print(f"\nsum: doubling {sum(doubling.values())}, "
          f"greedy {sum(greedy.values())} (capacity {C})")
    exact_p2 = S.exact_dp(jobs, C, max_w=256, powers_of_two=True)
    t_e = S.total_time(jobs, exact_p2)
    print(f"total completion (s-units): doubling {t_d:.0f}, "
          f"greedy {t_g:.0f}, exact-pow2 {t_e:.0f}")
    print(f"doubling is within {100*(t_d/t_e-1):.1f}% of the exact "
          f"power-of-two optimum.")
    bad = [w for w in greedy.values() if w & (w - 1)]
    print(f"NOTE: greedy's {len(bad)} non-power-of-two allocations "
          f"{sorted(bad)} are not realizable TPU slices — on a torus, the "
          f"paper's power-of-two restriction is structural, so the "
          f"doubling heuristic gives up nothing and stays near-optimal.")


if __name__ == "__main__":
    main()
