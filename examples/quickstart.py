"""Quickstart: train a small LM end-to-end with the public API.

Default is a ~10M-param model for 200 steps (CPU-tractable); pass
``--size 100m --steps 300`` on real hardware for the ~100M run the
production config targets.

  PYTHONPATH=src python examples/quickstart.py
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import TokenStream
from repro.engine.steps import make_train_step, init_train_state
from repro.models import spec as pspec
from repro.models.registry import build_model
from repro.optim.optimizers import adamw
from repro.optim.schedule import warmup_cosine

SIZES = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "10m": (4, 256, 4, 2, 1024, 8192),
    "100m": (12, 768, 12, 4, 3072, 32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(SIZES), default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    L, D, H, KV, F, V = SIZES[args.size]
    cfg = dataclasses.replace(
        get_config("qwen2.5-3b"), name=f"quickstart-{args.size}",
        n_layers=L, d_model=D, n_heads=H, n_kv_heads=KV, d_head=D // H,
        d_ff=F, vocab_size=V)
    model = build_model(cfg)
    print(f"{cfg.name}: {pspec.n_params(model.param_specs())/1e6:.1f}M params")

    opt = adamw()
    state = init_train_state(model, opt)
    step = jax.jit(make_train_step(model, opt))
    data = TokenStream(V, args.seq, seed=0)
    sched = warmup_cosine(3e-4, warmup=20, total=args.steps)

    for i in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(i, args.batch).items()}
        state, loss = step(state, batch, jnp.float32(sched(i)))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}")
    print("done.")


if __name__ == "__main__":
    main()
