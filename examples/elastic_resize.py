"""Elastic checkpoint-stop-restart (paper Table 2, scaled to this host).

Trains the paper's ResNet/CIFAR workload at w=4, checkpoints, restarts at
w=8 with the eq. (7) LR rescale, and reports the measured stop/restart cost
— the feasibility result at the heart of the paper (§6).

  PYTHONPATH=src python examples/elastic_resize.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro.checkpoint.store import CheckpointStore
from repro.configs.resnet110 import ResNetConfig
from repro.core.elastic import ElasticTrainer
from repro.data.synthetic import CifarLike
from repro.models.resnet import ResNetModel
from repro.optim.optimizers import sgd


def main():
    cfg = ResNetConfig(name="resnet14", depth=14, width=8)
    model = ResNetModel(cfg)
    data = CifarLike(size=2048, seed=0)
    with tempfile.TemporaryDirectory() as d:
        tr = ElasticTrainer(model, sgd(), data, CheckpointStore(d),
                            base_lr_1w=0.02, m_per_worker=16,
                            dataset_size=2048)
        print("=== segment 1: w=4 ===")
        r1 = tr.train_segment(w=4, n_steps=30, resume=False, log_every=6)
        for s, e, l in r1.losses:
            print(f"  step {s:3d} epoch {e:5.2f} loss {l:.4f}")
        print(f"  checkpoint saved in {r1.save_seconds*1e3:.0f} ms")

        print("=== stop; restart at w=8 (lr x2, eq. 7) ===")
        r2 = tr.train_segment(w=8, n_steps=15, resume=True, log_every=3)
        print(f"  restored in {r2.restore_seconds*1e3:.0f} ms")
        for s, e, l in r2.losses:
            print(f"  step {s:3d} epoch {e:5.2f} loss {l:.4f}")
        cost = r1.save_seconds + r2.restore_seconds
        print(f"stop+restart cost: {cost:.2f} s "
              f"(paper measured ~10 s at K40m/ResNet-110 scale)")
        assert r2.losses[-1][2] < r1.losses[0][2]
        print("convergence continued across the resize — OK")


if __name__ == "__main__":
    main()
