"""Scheduler tests: doubling heuristic vs Optimus greedy vs exact DP,
capacity safety (hypothesis), and the paper's central greedy-trap claim."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.collectives import cost as C
from repro.core import scheduler as S
from repro.core.jobs import JobSpec


def make_jobs(n_jobs, n_bytes=6.9e6, seed=0, speed_mode="analytic"):
    rng = np.random.default_rng(seed)
    jobs = []
    for j in range(n_jobs):
        spec = JobSpec(job_id=j, arrival=0.0,
                       epochs=float(rng.uniform(100, 200)),
                       n_bytes=n_bytes, speed_mode=speed_mode)
        jobs.append((j, spec.epochs, spec.speed))
    return jobs


@settings(max_examples=25, deadline=None)
@given(n_jobs=st.integers(1, 12), capacity=st.integers(1, 64))
def test_doubling_respects_capacity(n_jobs, capacity):
    jobs = make_jobs(n_jobs)
    alloc = S.doubling_heuristic(jobs, capacity, max_w=8)
    assert sum(alloc.values()) <= capacity
    assert all(w >= 0 for w in alloc.values())
    # power-of-two allocations only (the doubling invariant)
    assert all(w == 0 or (w & (w - 1)) == 0 for w in alloc.values())


@settings(max_examples=25, deadline=None)
@given(n_jobs=st.integers(1, 12), capacity=st.integers(1, 64))
def test_greedy_respects_capacity(n_jobs, capacity):
    jobs = make_jobs(n_jobs)
    alloc = S.optimus_greedy(jobs, capacity, max_w=8)
    assert sum(alloc.values()) <= capacity


def test_all_jobs_get_one_worker_when_feasible():
    jobs = make_jobs(8)
    alloc = S.doubling_heuristic(jobs, 8)
    assert all(w == 1 for w in alloc.values())


def test_fifo_when_oversubscribed():
    jobs = make_jobs(10)
    alloc = S.doubling_heuristic(jobs, 4)
    assert [alloc[j] for j in range(10)] == [1, 1, 1, 1] + [0] * 6


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_doubling_close_to_exact_dp(seed):
    jobs = make_jobs(4, seed=seed)
    cap = 16
    exact = S.exact_dp(jobs, cap, max_w=8)
    doubling = S.doubling_heuristic(jobs, cap, max_w=8)
    t_exact = S.total_time(jobs, exact)
    t_doub = S.total_time(jobs, doubling)
    assert t_doub <= 1.35 * t_exact, (t_doub, t_exact)


def test_exact_dp_pow2_at_least_unrestricted():
    jobs = make_jobs(3, seed=3)
    t_any = S.total_time(jobs, S.exact_dp(jobs, 12, max_w=8))
    t_p2 = S.total_time(jobs, S.exact_dp(jobs, 12, max_w=8,
                                         powers_of_two=True))
    assert t_p2 >= t_any - 1e-9


def test_doubling_escapes_greedy_trap():
    """Paper §4.2: at LLM-scale n every w -> w+1 step that leaves a power
    of two swaps eq.(3) for the costlier eq.(4), so +1 greedy's marginal
    gain is NEGATIVE at the first boundary it meets and the job never
    grows, even though doubling to a larger power of two is a big win.
    One big job, ample capacity."""
    big = JobSpec(job_id=0, arrival=0.0, epochs=150.0, n_bytes=4e9,
                  speed_mode="analytic", max_w=64,
                  hw=C.TPU_V5E)
    jobs = [(0, big.epochs, big.speed)]
    cap = 32
    # sanity: pow2 growth helps, +1 across the boundary regresses
    assert big.speed(2) > big.speed(1)
    assert big.speed(3) < big.speed(2)          # the first cliff
    assert big.speed(16) > big.speed(8) > big.speed(4)
    g = S.optimus_greedy(jobs, cap, max_w=64)
    d = S.doubling_heuristic(jobs, cap, max_w=64)
    assert g[0] < d[0], (g, d)    # greedy stalls at its first cliff
    assert d[0] >= 16, d          # doubling reaches a large allocation
    assert (S.total_time(jobs, d) < 0.5 * S.total_time(jobs, g))


def test_gain_formula_is_eq6():
    """The doubling score is exactly (Q/f(w) - Q/f(2w)) / w."""
    f = lambda w: float(w)        # linear speedup
    Q = 100.0
    g = S._gain_double(Q, f, 4)
    assert abs(g - (Q / 4 - Q / 8) / 4) < 1e-12
