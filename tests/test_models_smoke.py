"""Per-architecture smoke tests (deliverable f): a REDUCED same-family
variant runs one forward/train step and one decode step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.shapes import InputShape
from repro.models import spec as pspec
from repro.models.registry import build_model

TRAIN = InputShape("t", 32, 2, "train")
DECODE = InputShape("d", 64, 2, "decode")


def make_batch(model, shape, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    for k, s in model.input_specs(shape).items():
        if s.dtype == jnp.int32:
            if k == "pos":
                batch[k] = jnp.asarray(rng.integers(1, shape.seq_len - 1,
                                                    s.shape), jnp.int32)
            else:
                batch[k] = jnp.asarray(
                    rng.integers(0, 100, s.shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.normal(size=s.shape) * 0.1, s.dtype)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(model, TRAIN)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    norms = [float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = pspec.init_params(jax.random.PRNGKey(1),
                              model.cache_specs(DECODE))
    batch = make_batch(model, DECODE)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache structure preserved
    assert (jax.tree_util.tree_structure(new_cache)
            == jax.tree_util.tree_structure(cache))


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_loss_decreases(arch):
    """A few SGD steps on a fixed batch must reduce the loss."""
    from repro.optim.optimizers import adamw
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = adamw()
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = make_batch(model, TRAIN)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, 3e-3)
        return params, opt_state, loss

    losses = []
    for _ in range(8):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
