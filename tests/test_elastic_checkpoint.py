"""Elastic stop/restart (paper §5-6) + checkpoint store tests."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs.resnet110 import smoke_config
from repro.core.elastic import ElasticTrainer
from repro.data.synthetic import CifarLike, TokenStream
from repro.models.resnet import ResNetModel
from repro.optim.optimizers import sgd, adamw
from repro.optim.schedule import rescale_lr, step_decay


def test_checkpoint_roundtrip_exact():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                            "b": jnp.ones((3,))},
                 "step": jnp.asarray(7, jnp.int32)}
        store.save(7, state, meta={"w": 4})
        restored, meta, _ = store.restore(state)
        assert meta == {"w": 4}
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(1, {"a": jnp.ones(3)})
        with pytest.raises(KeyError):
            store.restore({"a": jnp.ones(3), "b": jnp.ones(2)})


def test_latest_step():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        assert store.latest_step() is None
        store.save(3, {"x": jnp.ones(1)})
        store.save(12, {"x": jnp.ones(1)})
        assert store.latest_step() == 12
        assert store.steps() == [3, 12]


def test_lr_rescale_eq7():
    assert rescale_lr(0.1, 8, 4) == pytest.approx(0.2)
    assert rescale_lr(0.4, 8, 4) == pytest.approx(0.8)  # paper's 4->8 case
    assert rescale_lr(0.8, 4, 8) == pytest.approx(0.4)  # shrink too


def test_step_decay_boundaries_shift_with_batch():
    """Decay is pinned to epochs: with 2x the workers (2x global batch),
    the step boundary halves — exactly §5's adjustment."""
    spe_4 = 50000 / (128 * 4)
    spe_8 = 50000 / (128 * 8)
    lr4 = step_decay(0.4, spe_4)
    lr8 = step_decay(0.8, spe_8)
    b4 = next(s for s in range(100_000) if lr4(s) < 0.4)
    b8 = next(s for s in range(100_000) if lr8(s) < 0.8)
    assert abs(b4 - 2 * b8) <= 2


def test_elastic_resize_preserves_state_and_learns():
    cfg = smoke_config()
    model = ResNetModel(cfg)
    data = CifarLike(size=512, seed=0)
    with tempfile.TemporaryDirectory() as d:
        tr = ElasticTrainer(model, sgd(), data, CheckpointStore(d),
                            base_lr_1w=0.05, m_per_worker=16,
                            dataset_size=512)
        r1 = tr.train_segment(w=1, n_steps=12, resume=False, log_every=4)
        r2 = tr.train_segment(w=2, n_steps=10, resume=True, log_every=4)
        # epochs accumulate across the resize (m stays per-worker)
        assert r2.epochs > r1.epochs
        # learning continues: the post-resize segment's *average* loss
        # beats the cold-start loss.  A single final-batch loss is too
        # noisy at this scale (22 SGD steps, batch 16-32) and made the
        # assertion flaky (ISSUE 2); averaging the segment keeps the
        # "still learning after the resize" signal without the noise.
        seg2_avg = np.mean([loss for _, _, loss in r2.losses])
        assert seg2_avg < r1.losses[0][2]
        # stop+restart cost exists and is small (paper: ~10 s at K40m scale)
        assert 0 < r1.save_seconds < 5
        assert 0 < r2.restore_seconds < 5


def test_elastic_restart_is_exact_resume():
    """Restarting at the same w must continue the exact same trajectory as
    not stopping at all (checkpoint carries params+momentum+step)."""
    cfg = smoke_config()
    model = ResNetModel(cfg)
    data = CifarLike(size=256, seed=1)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        a = ElasticTrainer(model, sgd(), data, CheckpointStore(d1),
                           base_lr_1w=0.05, m_per_worker=8,
                           dataset_size=256)
        r = a.train_segment(w=1, n_steps=10, resume=False, log_every=1)
        uninterrupted = [l for _, _, l in r.losses]

        b = ElasticTrainer(model, sgd(), data, CheckpointStore(d2),
                           base_lr_1w=0.05, m_per_worker=8,
                           dataset_size=256)
        b.train_segment(w=1, n_steps=5, resume=False, log_every=1)
        r2 = b.train_segment(w=1, n_steps=5, resume=True, log_every=1)
        resumed = [l for _, _, l in r2.losses]
        np.testing.assert_allclose(resumed, uninterrupted[5:], rtol=1e-5)


def test_token_stream_deterministic_and_learnable():
    ts = TokenStream(64, 16, seed=0)
    b1 = ts.batch(3, 4)
    b2 = ts.batch(3, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_cifar_like_epoch_wraps():
    data = CifarLike(size=100, seed=0)
    b = data.batch(0, 64)
    assert b["images"].shape == (64, 32, 32, 3)
    assert data.steps_per_epoch(50) == 2.0
