"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,d,window,causal", [
    (2, 256, 64, None, True),
    (2, 256, 64, 128, True),
    (1, 384, 128, 96, True),
    (3, 128, 128, None, False),
    (1, 130, 32, 64, True),          # non-multiple seq (padding path)
    (2, 64, 256, 32, True),          # gemma-style d=256
])
def test_swa_attention_sweep(bh, s, d, window, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = jax.random.normal(k1, (bh, s, d), dtype)
    k = jax.random.normal(k2, (bh, s, d), dtype)
    v = jax.random.normal(k3, (bh, s, d), dtype)
    got = ops.swa_attention(q, k, v, causal=causal, window=window,
                            block_q=64, block_k=64)
    want = ref.swa_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_swa_attention_block_shape_invariance():
    """BlockSpec tile sizes must not change results."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (2, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 256, 64), jnp.float32)
    outs = [ops.swa_attention(q, k, v, window=100, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


def test_swa_window_blocks_are_skipped_semantically():
    """With a tiny window, far-away K must have zero influence."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 256, 32), jnp.float32)
    k = jax.random.normal(k2, (1, 256, 32), jnp.float32)
    v = jax.random.normal(k3, (1, 256, 32), jnp.float32)
    base = ops.swa_attention(q, k, v, window=16, block_q=64, block_k=64)
    k2_, v2_ = k.at[:, :128].set(99.0), v.at[:, :128].set(-99.0)
    pert = ops.swa_attention(q, k2_, v2_, window=16, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(pert[:, 192:]),
                               np.asarray(base[:, 192:]), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 5000), lr=st.floats(1e-4, 1.0),
       momentum=st.floats(0.0, 0.99))
def test_fused_update_property(n, lr, momentum):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n), 3)
    p = jax.random.normal(k1, (n,), jnp.float32)
    g = jax.random.normal(k2, (n,), jnp.float32)
    mu = jax.random.normal(k3, (n,), jnp.float32)
    got = ops.fused_sgd_update(p, g, mu, lr, momentum=momentum, block=512)
    want = ref.fused_sgd_update_ref(p, g, mu, lr, momentum=momentum)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("nesterov", [False, True])
@pytest.mark.parametrize("n,block", [(65536, 65536), (100001, 4096),
                                     (7, 8)])
def test_fused_update_shapes(n, block, nesterov):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    p = jax.random.normal(k1, (n,), jnp.float32)
    g = jax.random.normal(k2, (n,), jnp.float32)
    mu = jax.random.normal(k3, (n,), jnp.float32)
    got = ops.fused_sgd_update(p, g, mu, 0.1, nesterov=nesterov, block=block)
    want = ref.fused_sgd_update_ref(p, g, mu, 0.1, nesterov=nesterov)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)


def test_fused_update_equals_sgd_optimizer_step():
    """The kernel is a drop-in for the jnp SGD update on a flat buffer."""
    from repro.optim.optimizers import sgd
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    params = {"a": jax.random.normal(k1, (33,)),
              "b": jax.random.normal(k2, (17,))}
    grads = jax.tree_util.tree_map(lambda x: x * 0.1, params)
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params, 0.05)

    flat_p = jnp.concatenate([params["a"], params["b"]])
    flat_g = jnp.concatenate([grads["a"], grads["b"]])
    flat_mu = jnp.zeros_like(flat_p)
    got_p, got_mu = ops.fused_sgd_update(flat_p, flat_g, flat_mu, 0.05,
                                         momentum=0.9, weight_decay=1e-4,
                                         block=32)
    want_p = jnp.concatenate([new_params["a"], new_params["b"]])
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,block_rows", [
    ((4, 128, 512), 256), ((1, 7, 64), 4), ((300, 1024), 128),
    ((2, 2048), 2048)])
def test_rmsnorm_sweep(shape, block_rows, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(sum(shape)))
    x = jax.random.normal(k1, shape, dtype)
    w = jax.random.normal(k2, (shape[-1],), jnp.float32) * 0.1
    got = ops.rmsnorm(x, w, block_rows=block_rows)
    want = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_matches_model_layer():
    """The kernel is a drop-in for repro.models.layers.rmsnorm."""
    from repro.models.layers import rmsnorm as layer_rmsnorm
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (3, 17, 256), jnp.float32)
    w = jax.random.normal(k2, (256,), jnp.float32) * 0.1
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w)),
                               np.asarray(layer_rmsnorm(x, w)),
                               rtol=1e-5, atol=1e-5)
