"""Parity of the table-driven solvers with the callable/reference solvers.

Property-style (randomized, seeded — no hypothesis dependency): on random
(Q, speed-table) instances the lazy-heap table solvers must return the
exact allocation the original O(J)-rescan implementations (the
``repro.core._reference`` parity oracle) return, and
``exact_dp(powers_of_two=True)`` must lower-bound the doubling heuristic's
total time (the heuristic emits only power-of-two allocations).
"""
import numpy as np
import pytest

from repro.core import _reference as R
from repro.core import scheduler as S
from repro.core.jobs import JobSpec


def random_instance(rng, n_jobs, bound):
    """Random jobs as (callable list, table list) over the same speeds."""
    jobs_callable, jobs_table = [], []
    for j in range(n_jobs):
        Q = float(rng.uniform(50, 250))
        speeds = np.cumsum(rng.uniform(0.05, 1.0, bound))  # increasing-ish
        if rng.random() < 0.5:     # non-monotone tail: scaling cliffs
            k = int(rng.integers(1, bound + 1))
            speeds[k - 1:] *= float(rng.uniform(0.3, 1.0))
        if rng.random() < 0.3 and bound >= 4:
            speeds[1] = speeds[0] * 2.0   # exact-tie gains across jobs
            speeds[3] = speeds[1] * 2.0
        table = [0.0] + [float(s) for s in speeds]
        jobs_callable.append((j, Q, lambda w, t=table: t[w]))
        jobs_table.append((j, Q, table))
    return jobs_callable, jobs_table


@pytest.mark.parametrize("seed", range(8))
def test_doubling_table_matches_callable(seed):
    rng = np.random.default_rng(seed)
    for _ in range(25):
        n_jobs = int(rng.integers(1, 13))
        capacity = int(rng.integers(1, 65))
        max_w = [None, 4, 8, 16][int(rng.integers(0, 4))]
        bound = S._table_bound(capacity, max_w)
        jc, jt = random_instance(rng, n_jobs, bound)
        assert (S.doubling_heuristic_table(jt, capacity, max_w)
                == R.doubling_heuristic_ref(jc, capacity, max_w))
        # thin adapter delegates to the same solver
        assert (S.doubling_heuristic(jc, capacity, max_w)
                == R.doubling_heuristic_ref(jc, capacity, max_w))


@pytest.mark.parametrize("seed", range(8))
def test_doubling_soa_matches_reference(seed):
    """The SoA solver (ndarray in, ndarray out — the simulator hot path)
    must allocate exactly like the seed rescan, including tie-breaks, both
    with contiguous rows and through a scattered ``rows`` view."""
    rng = np.random.default_rng(200 + seed)
    for _ in range(25):
        n_jobs = int(rng.integers(1, 13))
        capacity = int(rng.integers(1, 65))
        max_w = [None, 4, 8, 16][int(rng.integers(0, 4))]
        bound = S._table_bound(capacity, max_w)
        jc, jt = random_instance(rng, n_jobs, bound)
        want = R.doubling_heuristic_ref(jc, capacity, max_w)
        Q = np.array([q for (_, q, _) in jt])
        tables = np.array([t for (_, _, t) in jt])
        got = S.doubling_heuristic_soa(Q, tables, capacity, max_w)
        assert {j: int(w) for (j, _, _), w in zip(jt, got)} == want
        # scattered rows: interleave the jobs into a larger table matrix
        big = np.zeros((2 * n_jobs, bound + 1))
        rows = np.arange(n_jobs) * 2 + 1
        big[rows] = tables
        got2 = S.doubling_heuristic_soa(Q, big, capacity, max_w, rows=rows)
        assert np.array_equal(got, got2)


@pytest.mark.parametrize("seed", range(4))
def test_per_job_caps_respected_and_consistent(seed):
    """Per-job max_w (heterogeneous fleets): no job is ever doubled past
    its own cap, a homogeneous cap list behaves exactly like the scalar,
    and ref / table / SoA agree allocation-for-allocation."""
    rng = np.random.default_rng(300 + seed)
    for _ in range(25):
        n_jobs = int(rng.integers(1, 13))
        capacity = int(rng.integers(1, 65))
        bound = S._table_bound(capacity, 16)
        jc, jt = random_instance(rng, n_jobs, bound)
        caps = [int(c) for c in rng.choice([2, 4, 8, 16], n_jobs)]
        want = R.doubling_heuristic_ref(jc, capacity, max_w=caps)
        assert all(want[j] <= caps[j] for j in range(n_jobs))
        assert S.doubling_heuristic_table(jt, capacity, max_w=caps) == want
        Q = np.array([q for (_, q, _) in jt])
        tables = np.array([t for (_, _, t) in jt])
        got = S.doubling_heuristic_soa(Q, tables, capacity,
                                       max_w=np.array(caps))
        assert {j: int(w) for (j, _, _), w in zip(jt, got)} == want
        # scalar == homogeneous per-job list
        assert (R.doubling_heuristic_ref(jc, capacity, max_w=8)
                == R.doubling_heuristic_ref(jc, capacity,
                                            max_w=[8] * n_jobs))


def test_fixed_soa_matches_fixed():
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(1, 20))
        capacity = int(rng.integers(1, 65))
        k = int(rng.integers(1, capacity + 1))
        jobs = [(j, 1.0, None) for j in range(n)]
        want = S.fixed(jobs, capacity, k)
        got = S.fixed_soa(n, capacity, k)
        assert {j: int(w) for j, w in enumerate(got)} == want


@pytest.mark.parametrize("seed", range(8))
def test_optimus_table_matches_callable(seed):
    rng = np.random.default_rng(100 + seed)
    for _ in range(25):
        n_jobs = int(rng.integers(1, 13))
        capacity = int(rng.integers(1, 65))
        max_w = [None, 4, 8, 16][int(rng.integers(0, 4))]
        bound = S._table_bound(capacity, max_w)
        jc, jt = random_instance(rng, n_jobs, bound)
        assert (S.optimus_greedy_table(jt, capacity, max_w)
                == R.optimus_greedy_ref(jc, capacity, max_w))
        assert (S.optimus_greedy(jc, capacity, max_w)
                == R.optimus_greedy_ref(jc, capacity, max_w))


@pytest.mark.parametrize("seed", range(4))
def test_exact_dp_table_matches_callable(seed):
    rng = np.random.default_rng(200 + seed)
    for _ in range(10):
        n_jobs = int(rng.integers(1, 6))
        capacity = int(rng.integers(n_jobs, 21))
        max_w = [None, 4, 8][int(rng.integers(0, 3))]
        bound = S._table_bound(capacity, max_w)
        jc, jt = random_instance(rng, n_jobs, bound)
        for p2 in (False, True):
            assert (S.exact_dp_table(jt, capacity, max_w, powers_of_two=p2)
                    == R.exact_dp_ref(jc, capacity, max_w, powers_of_two=p2))
            assert (S.exact_dp(jc, capacity, max_w, powers_of_two=p2)
                    == R.exact_dp_ref(jc, capacity, max_w, powers_of_two=p2))


@pytest.mark.parametrize("seed", range(6))
def test_exact_dp_pow2_lower_bounds_doubling(seed):
    """The doubling heuristic allocates only powers of two, so the exact DP
    restricted to power-of-two choices can never be slower."""
    rng = np.random.default_rng(300 + seed)
    n_jobs = int(rng.integers(1, 6))
    capacity = int(rng.integers(n_jobs, 33))
    bound = S._table_bound(capacity, 8)
    jc, jt = random_instance(rng, n_jobs, bound)
    doubling = S.doubling_heuristic_table(jt, capacity, max_w=8)
    assert all(w == 0 or (w & (w - 1)) == 0 for w in doubling.values())
    exact_p2 = S.exact_dp_table(jt, capacity, max_w=8, powers_of_two=True)
    t_exact = S.total_time(jc, exact_p2)
    t_doub = S.total_time(jc, doubling)
    assert t_exact <= t_doub + 1e-9


def test_speed_table_matches_scalar_speed():
    """JobSpec.speed_table must be bit-identical to scalar speed() calls —
    the contract the simulator's bit-identical-trajectory promise rests on."""
    from repro.collectives import cost as C
    cases = [
        dict(speed_mode="table2"),
        dict(speed_mode="analytic"),
        dict(speed_mode="analytic", n_bytes=4e9, max_w=64, hw=C.TPU_V5E),
        dict(speed_mode="table2", max_w=64),
    ]
    for i, kw in enumerate(cases):
        spec = JobSpec(job_id=i, arrival=0.0, epochs=150.0, **kw)
        tab = spec.speed_table()
        ref = np.array([spec.speed(w) for w in range(spec.max_w + 1)])
        assert np.array_equal(tab, ref), kw
        assert not tab.flags.writeable          # cached array is read-only
        assert spec.speed_table() is tab        # and actually cached


def test_adapter_preserves_greedy_trap():
    """The callable adapter keeps the paper's §4.2 qualitative result."""
    from repro.collectives import cost as C
    big = JobSpec(job_id=0, arrival=0.0, epochs=150.0, n_bytes=4e9,
                  speed_mode="analytic", max_w=64, hw=C.TPU_V5E)
    jobs = [(0, big.epochs, big.speed)]
    tjobs = [(0, big.epochs, big.speed_table(32).tolist())]
    g = S.optimus_greedy_table(tjobs, 32, max_w=64)
    d = S.doubling_heuristic_table(tjobs, 32, max_w=64)
    assert g[0] < d[0] and d[0] >= 16
