"""Attention layer tests: chunked == naive, window masks, RoPE properties
(incl. hypothesis sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models import layers as L


def naive_attention(q, k, v, causal=True, window=None):
    b, s, h, d = q.shape
    scores = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
    qpos = np.arange(s)[:, None]
    kpos = np.arange(s)[None, :]
    mask = np.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = np.where(mask[None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("s,q_chunk,window", [
    (32, 8, None), (32, 8, 8), (33, 16, 5), (16, 16, None), (40, 7, 16)])
def test_chunked_matches_naive(s, q_chunk, window):
    rng = np.random.default_rng(0)
    q = rng.normal(size=(2, s, 3, 8)).astype(np.float32)
    k = rng.normal(size=(2, s, 3, 8)).astype(np.float32)
    v = rng.normal(size=(2, s, 3, 8)).astype(np.float32)
    got = L.chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=True, window=window, q_chunk=q_chunk)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_naive_last_row():
    rng = np.random.default_rng(1)
    s, h, d = 24, 2, 16
    q = rng.normal(size=(2, 1, h, d)).astype(np.float32)
    kc = rng.normal(size=(2, s, h, d)).astype(np.float32)
    vc = rng.normal(size=(2, s, h, d)).astype(np.float32)
    pos = np.array([10, 23], np.int32)
    got = L.decode_attention(jnp.asarray(q), jnp.asarray(kc),
                             jnp.asarray(vc), jnp.asarray(pos), window=8)
    for b in range(2):
        t = pos[b]
        kk = kc[b:b + 1, :t + 1]
        vv = vc[b:b + 1, :t + 1]
        full_q = np.concatenate([np.zeros((1, t, h, d), np.float32),
                                 q[b:b + 1]], axis=1)
        want = naive_attention(full_q, kk, vv, causal=True, window=8)[0, -1]
        np.testing.assert_allclose(np.asarray(got[b, 0]), want,
                                   rtol=2e-4, atol=2e-4)


def test_window_1_attends_self_only():
    """window=1 => output is exactly V at each position."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 8, 2, 4)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 8, 2, 4)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 8, 2, 4)).astype(np.float32))
    out = L.chunked_attention(q, k, v, causal=True, window=1, q_chunk=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(s=st.integers(2, 40), h=st.integers(1, 4),
       window=st.one_of(st.none(), st.integers(1, 40)),
       q_chunk=st.integers(1, 16))
def test_chunked_attention_property(s, h, window, q_chunk):
    """Property: chunking never changes the result."""
    rng = np.random.default_rng(s * 100 + h)
    q = rng.normal(size=(1, s, h, 4)).astype(np.float32)
    k = rng.normal(size=(1, s, h, 4)).astype(np.float32)
    v = rng.normal(size=(1, s, h, 4)).astype(np.float32)
    got = L.chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=True, window=window, q_chunk=q_chunk)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=3e-4, atol=3e-4)


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 6, 2, 16)).astype(np.float32))
    pos = jnp.arange(6)[None]
    y = L.apply_rope(x, pos, 10_000.0)
    # rotation preserves per-head norms
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    def dot_at(p1, p2):
        rq = L.apply_rope(q, jnp.array([[p1]]), 10_000.0)
        rv = L.apply_rope(v, jnp.array([[p2]]), 10_000.0)
        return float(jnp.sum(rq * rv))
    assert abs(dot_at(0, 5) - dot_at(7, 12)) < 1e-4
    assert abs(dot_at(0, 5) - dot_at(0, 6)) > 1e-6  # but not constant


def test_mrope_sections():
    x = jnp.ones((1, 4, 1, 12), jnp.float32)
    pos3 = jnp.stack([jnp.arange(4), jnp.arange(4) * 2, jnp.arange(4) * 3],
                     axis=-1)[None]
    y = L.apply_rope(x, pos3, 10_000.0, sections=(2, 2, 2))
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
