"""Optional-hypothesis shim.

The property-based tests use ``hypothesis``, which is not part of the
baked container image.  Importing through this module keeps the test
modules collectible either way: with hypothesis installed the real
``given``/``settings``/``strategies`` are re-exported; without it the
property tests are collected as individual skips and every non-property
test in the same module still runs.

Usage (replaces ``from hypothesis import given, settings, strategies``):

    from _hypothesis_compat import given, settings, strategies
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in: supports chaining (.map/.filter/...) and |."""

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

        def __or__(self, other):
            return _Strategy()

        def __ror__(self, other):
            return _Strategy()

    class _StrategiesModule:
        """Any strategy constructor (integers, floats, lists, ...) works."""

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

    strategies = _StrategiesModule()

    def settings(*_args, **_kwargs):
        """Decorator factory: identity (also tolerates bare use)."""
        if _args and callable(_args[0]) and len(_args) == 1 and not _kwargs:
            return _args[0]
        return lambda fn: fn

    def given(*_args, **_kwargs):
        """Replace the property test with a zero-argument skipper so pytest
        neither demands fixtures for the strategy parameters nor loses the
        test from the report."""

        def deco(fn):
            def _skipped_property_test():
                pytest.skip("hypothesis not installed")

            _skipped_property_test.__name__ = fn.__name__
            _skipped_property_test.__doc__ = fn.__doc__
            return _skipped_property_test

        return deco
