"""The assigned architecture table, asserted exactly."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config

EXPECTED = {
    "qwen2.5-3b": dict(family="dense", n_layers=36, d_model=2048, n_heads=16,
                       n_kv_heads=2, d_ff=11008, vocab_size=151936,
                       qkv_bias=True),
    "qwen2-vl-2b": dict(family="vlm", n_layers=28, d_model=1536, n_heads=12,
                        n_kv_heads=2, d_ff=8960, vocab_size=151936,
                        mrope=True),
    "h2o-danube-1.8b": dict(family="dense", n_layers=24, d_model=2560,
                            n_heads=32, n_kv_heads=8, d_ff=6912,
                            vocab_size=32000, sliding_window=4096),
    "mamba2-780m": dict(family="ssm", n_layers=48, d_model=1536,
                        vocab_size=50280, ssm_state=128, d_ff=0),
    "jamba-v0.1-52b": dict(family="hybrid", n_layers=32, d_model=4096,
                           n_heads=32, n_kv_heads=8, d_ff=14336,
                           vocab_size=65536, n_experts=16, top_k=2,
                           attn_every=8),
    "qwen3-moe-30b-a3b": dict(family="moe", n_layers=48, d_model=2048,
                              n_heads=32, n_kv_heads=4, d_ff=768,
                              vocab_size=151936, n_experts=128, top_k=8),
    "gemma-2b": dict(family="dense", n_layers=18, d_model=2048, n_heads=8,
                     n_kv_heads=1, d_ff=16384, vocab_size=256000, d_head=256,
                     activation="geglu"),
    "dbrx-132b": dict(family="moe", n_layers=40, d_model=6144, n_heads=48,
                      n_kv_heads=8, d_ff=10752, vocab_size=100352,
                      n_experts=16, top_k=4),
    "whisper-base": dict(family="audio", n_layers=6, d_model=512, n_heads=8,
                         n_kv_heads=8, d_ff=2048, vocab_size=51865,
                         encoder_layers=6),
    "qwen2.5-14b": dict(family="dense", n_layers=48, d_model=5120,
                        n_heads=40, n_kv_heads=8, d_ff=13824,
                        vocab_size=152064, qkv_bias=True),
}


def test_all_ten_archs_present():
    assert set(ARCH_IDS) == set(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_config_numbers(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_smoke_configs_are_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.vocab_size <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen2.5-14b", "gemma-2b",
                                  "mamba2-780m", "jamba-v0.1-52b",
                                  "dbrx-132b", "qwen3-moe-30b-a3b"])
def test_param_counts_in_expected_range(arch):
    """Full-config parameter counts should be near the advertised sizes."""
    bounds = {"qwen2.5-3b": (2.5e9, 4e9), "qwen2.5-14b": (12e9, 16e9),
              "gemma-2b": (2e9, 3.2e9), "mamba2-780m": (0.6e9, 1.0e9),
              "jamba-v0.1-52b": (45e9, 60e9), "dbrx-132b": (110e9, 145e9),
              "qwen3-moe-30b-a3b": (25e9, 35e9)}
    n = get_config(arch).param_count()
    lo, hi = bounds[arch]
    assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < total * 0.25          # 8/128 experts active + shared
    assert 2e9 <= active <= 5e9           # "a3b" = ~3B active
