"""All-reduce schedule simulators + cost-model cross-validation (the paper's
eqs. 2-4 against first-principles counters from executing the schedules)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.collectives import cost as C
from repro.collectives.schedules import (ALGORITHMS, best_algorithm,
                                         binary_blocks_allreduce,
                                         halving_doubling_allreduce,
                                         ring_allreduce)


@settings(max_examples=25, deadline=None)
@given(w=st.integers(1, 24), n=st.integers(1, 200))
def test_ring_exact(w, n):
    rng = np.random.default_rng(w * 1000 + n)
    v = rng.normal(size=(w, n))
    out, st_ = ring_allreduce(v)
    np.testing.assert_allclose(out, np.broadcast_to(v.sum(0), (w, n)),
                               atol=1e-9)
    assert st_.steps == (2 * (w - 1) if w > 1 else 0)


@settings(max_examples=15, deadline=None)
@given(logw=st.integers(0, 5), n=st.integers(1, 128))
def test_halving_doubling_exact(logw, n):
    w = 2 ** logw
    rng = np.random.default_rng(w * 999 + n)
    v = rng.normal(size=(w, n))
    out, st_ = halving_doubling_allreduce(v)
    np.testing.assert_allclose(out, np.broadcast_to(v.sum(0), (w, n)),
                               atol=1e-9)
    assert st_.steps == (2 * logw if w > 1 else 0)


@settings(max_examples=25, deadline=None)
@given(w=st.integers(1, 24), n=st.integers(1, 128))
def test_binary_blocks_exact(w, n):
    rng = np.random.default_rng(w * 7 + n)
    v = rng.normal(size=(w, n))
    out, _ = binary_blocks_allreduce(v)
    np.testing.assert_allclose(out, np.broadcast_to(v.sum(0), (w, n)),
                               atol=1e-9)


def test_ring_bandwidth_optimality():
    """Ring moves 2n(w-1)/w bytes per rank — within 1% of the 2n lower
    bound for large w (why Horovod uses it for big tensors)."""
    v = np.zeros((16, 16 * 64))
    _, st_ = ring_allreduce(v, itemsize=1)
    assert st_.bytes_sent <= 2 * v.shape[1] * (1 - 1 / 16) + 1e-9


def test_dh_latency_optimality():
    """Doubling–halving needs only 2 log2(w) rounds (the paper's low-latency
    claim for small tensors)."""
    for w in (2, 4, 8, 16, 32):
        _, st_ = halving_doubling_allreduce(np.zeros((w, 64)))
        assert st_.steps == 2 * int(np.log2(w))


@pytest.mark.parametrize("w", [2, 4, 8, 16])
def test_cost_model_matches_schedule_counters_dh(w):
    """Eq. (3)'s β/γ coefficients (4nβ, 2.5nγ) are upper bounds on the
    executed schedule's counters (2n(1-1/w)·2 sent, n(1-1/w) reduced);
    the α count 4log(w) is 2x the schedule's 2log(w) (the paper follows
    [11] which counts both directions).  Assert the documented ratios."""
    n = 1024
    _, st_ = halving_doubling_allreduce(np.zeros((w, n)), itemsize=1)
    assert st_.steps == 2 * int(np.log2(w))
    # executed bytes: 2n(1-1/w); eq.(3) charges 4n — ratio in [2, 4]
    ratio = 4 * n / st_.bytes_sent
    assert 2.0 - 1e-9 <= ratio <= 4.0 + 1e-9
    # executed reduced bytes: n(1-1/w); eq.(3) charges 2.5n — ratio in
    # [2.5, 5]
    ratio_g = 2.5 * n / st_.bytes_reduced
    assert 2.5 - 1e-9 <= ratio_g <= 5.0 + 1e-9


@pytest.mark.parametrize("w", [2, 3, 4, 6, 8, 16])
def test_cost_model_ordering(w):
    """At the paper's regime (n <= 1e7), doubling-halving beats ring for
    power-of-two w in the analytic models, matching §2.1."""
    n = 5e6
    hw = C.INFINIBAND_100G
    t_ring = C.t_ring(128, 1e-3, 2e-3, w, n, hw)
    t_dh = C.t_dh(128, 1e-3, 2e-3, w, n, hw)
    if w & (w - 1) == 0 and w > 1:
        assert best_algorithm(w, n) == "doubling_halving"
    else:
        if w > 1:
            assert best_algorithm(w, n) == "binary_blocks"


def test_simulated_vs_analytic_step_time():
    """First-principles (schedule-counter) step time and eq. (2)-(4) step
    time agree within 2.5x across algorithms and w (coefficient conventions
    differ; the scheduler only needs consistent relative ordering)."""
    for w in (2, 4, 8, 16):
        for alg in ("ring", "doubling_halving"):
            a = C.step_time(128, 1e-3, 2e-3, w, 5e6, algorithm=alg)
            s = C.simulated_step_time(128, 1e-3, 2e-3, w, 5e6, algorithm=alg)
            assert 0.4 < a / s < 2.5, (alg, w, a, s)


@pytest.mark.parametrize("hw", [C.TPU_V5E, C.INFINIBAND_100G],
                         ids=lambda h: h.name)
@pytest.mark.parametrize("n", [5e6, 4e9], ids=["small_n", "llm_n"])
def test_step_time_table_matches_scalar(hw, n):
    """The vectorized ``step_time_table`` must be bit-identical to scalar
    ``step_time`` at every worker count — straddling every power-of-two
    boundary up to 64 (where the algorithm choice flips between eq. 3/4
    and, past the n threshold, to ring) — on both hardware presets.  This
    is the contract ``JobSpec.speed_table`` (and therefore the simulator's
    bit-identical-trajectory promise) rests on."""
    m, tf, tb = 128, 108e-3 / 128, 236.5e-3 / 128
    ws = np.arange(1, 65)
    table = C.step_time_table(m, tf, tb, ws, n, hw)
    scalar = np.array([C.step_time(m, tf, tb, int(w), n, hw) for w in ws])
    assert np.array_equal(table, scalar)
    # the boundary rows really exercise both branches: w=2^k uses eq. (3)
    # (or ring at LLM n), 2^k +- 1 uses eq. (4)
    for w in (4, 8, 16, 32):
        assert best_algorithm(w, n) != best_algorithm(w + 1, n)


def test_step_time_table_scalar_input_roundtrip():
    """A 0-d input stays a 0-d/scalar-shaped result with the same value."""
    got = C.step_time_table(128, 1e-3, 2e-3, np.array(8), 5e6, C.TPU_V5E)
    want = C.step_time(128, 1e-3, 2e-3, 8, 5e6, C.TPU_V5E)
    assert float(got) == want


def test_pow2_cliff():
    """The 8->9 cliff (paper §4.2): crossing a power-of-two boundary swaps
    doubling-halving (eq. 3) for binary-blocks (eq. 4), whose 7nβ + 3nγ
    terms make the *per-GPU speed* f(w)∝w/t(w) regress at LLM-scale n,
    while 8->16 (still eq. 3) wins — the phenomenon the doubling heuristic
    exploits."""
    n = 4e9           # LLM-scale gradient (4 GB)
    m, tf, tb = 128, 1.3e-3, 1.4e-3
    hw = C.TPU_V5E
    t8 = C.t_dh(m, tf, tb, 8, n, hw)
    t9 = C.t_bb(m, tf, tb, 9, n, hw)
    t16 = C.t_dh(m, tf, tb, 16, n, hw)
    assert t9 > t8                       # 9 workers: slower steps
    assert 9 / t9 < 8 / t8               # and worse aggregate speed
    assert 16 / t16 > 1.5 * (8 / t8)     # 16 is a clear win
