"""Head padding (pad_heads_to): the shard-friendly padded-head model must be
mathematically IDENTICAL to the unpadded model — same logits, and exactly
zero gradient into the padded parameter slices (EXPERIMENTS.md §Perf A1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.registry import build_model


def _padded_from(params, pp_template, H_real):
    out = jax.tree_util.tree_map(lambda a: a, pp_template)
    for k in ("embed", "unembed"):
        if k in params:
            out[k] = params[k]
    out["final_norm"] = params["final_norm"]
    lp, lo = params["layers"], out["layers"]
    for k in ("ln1", "ln2", "mlp"):
        lo[k] = lp[k]
    a, ao = lp["attn"], lo["attn"]
    for key in ("wk", "wv", "bk", "bv"):
        if key in a:
            ao[key] = a[key]
    ao["wq"] = jnp.zeros_like(ao["wq"]).at[:, :, :H_real].set(a["wq"])
    ao["wo"] = jnp.zeros_like(ao["wo"]).at[:, :H_real].set(a["wo"])
    if "bq" in a:
        ao["bq"] = jnp.zeros_like(ao["bq"]).at[:, :H_real].set(a["bq"])
    return out


def test_padded_heads_identical_and_grad_isolated():
    # 5 heads -> padded to 8 (same ratio pathology as 40 -> 48 on 16)
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-14b"),
                              n_heads=5, n_kv_heads=1, d_head=32)
    cfgp = dataclasses.replace(cfg, pad_heads_to=8)
    m, mp = build_model(cfg), build_model(cfgp)
    params = m.init(jax.random.PRNGKey(0))
    pp = _padded_from(params, mp.init(jax.random.PRNGKey(1)), 5)

    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    l1, _ = m.forward(params, {"tokens": toks})
    l2, _ = mp.forward(pp, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    g = jax.grad(mp.loss)(pp, {"tokens": toks, "labels": toks})
    assert float(jnp.abs(g["layers"]["attn"]["wq"][:, :, 5:]).max()) == 0.0
    assert float(jnp.abs(g["layers"]["attn"]["wo"][:, 5:]).max()) == 0.0
    # real-head grads match the unpadded model's exactly
    g0 = jax.grad(m.loss)(params, {"tokens": toks, "labels": toks})
    np.testing.assert_allclose(
        np.asarray(g["layers"]["attn"]["wq"][:, :, :5]),
        np.asarray(g0["layers"]["attn"]["wq"]), rtol=1e-5, atol=1e-6)


def test_gqa_mapping_preserved_under_padding():
    """Padded model must keep each real head's original kv group."""
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-14b"),
                              n_heads=6, n_kv_heads=2, d_head=16)
    cfgp = dataclasses.replace(cfg, pad_heads_to=8)
    m, mp = build_model(cfg), build_model(cfgp)
    params = m.init(jax.random.PRNGKey(2))
    pp = _padded_from(params, mp.init(jax.random.PRNGKey(3)), 6)
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 12)), jnp.int32)
    l1, _ = m.forward(params, {"tokens": toks})
    l2, _ = mp.forward(pp, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
