"""Sharding-rule resolution: divisibility fallback, head_dim secondary
fallback, decode cache rules, and the no-duplicate-mesh-axis invariant."""
import os
import subprocess
import sys

import pytest
from _hypothesis_compat import given, settings, strategies as st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# rules resolution itself needs a Mesh object; build tiny abstract meshes in
# a subprocess-free way using jax's mesh_utils on 1 device is impossible for
# 16x16 — so use jax.sharding.Mesh over a numpy array of fake devices? Mesh
# requires real devices; we therefore test via AbstractMesh.
import jax
import numpy as np

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # older jax: no AbstractMesh/AxisType
    pytest.skip("jax.sharding lacks AbstractMesh/AxisType in this jax",
                allow_module_level=True)

from repro.sharding.rules import default_rules


def mesh_1pod():
    return AbstractMesh((16, 16), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)


def mesh_2pod():
    return AbstractMesh((2, 16, 16), ("pod", "data", "model"),
                        axis_types=(AxisType.Auto,) * 3)


RULES = default_rules()


def spec(axes, shape, mesh=None):
    return RULES.spec_for(axes, shape, mesh or mesh_1pod())


def test_batch_sharded_on_data():
    assert spec(("batch", "seq"), (256, 4096)) == P("data", None)


def test_batch_multi_pod():
    s = spec(("batch", "seq"), (256, 4096), mesh_2pod())
    assert s == P(("pod", "data"), None)


def test_batch_one_replicated():
    assert spec(("batch", "seq"), (1, 524288), mesh_2pod()) == P(None, None)


def test_heads_divisible():
    assert spec(("layers", "embed", "heads", "head_dim"),
                (36, 2048, 16, 128)) == P(None, None, "model", None)


def test_heads_40_falls_back_to_head_dim():
    """qwen2.5-14b: 40 heads don't divide 16 -> shard head_dim instead."""
    assert spec(("layers", "embed", "heads", "head_dim"),
                (48, 5120, 40, 128)) == P(None, None, None, "model")


def test_kv_heads_small_replicate():
    """kv=2 < 16 and q-heads divisible: kv weights replicate (GQA Megatron
    convention), no head_dim fallback."""
    assert spec(("layers", "embed", "kv_heads", "head_dim"),
                (36, 2048, 2, 128)) == P(None, None, None, None)


def test_vocab_non_divisible_replicates():
    assert spec(("vocab", "embed"), (51865, 512)) == P(None, None)
    assert spec(("vocab", "embed"), (151936, 2048)) == P("model", None)


def test_experts_sharded():
    assert spec(("layers", "experts", "embed", "mlp"),
                (48, 128, 2048, 768)) == P(None, "model", None, None)


def test_decode_cache_rules():
    # decode rules shard cache_seq over whatever axes batch leaves free
    rules = default_rules({"cache_seq": ("pod", "data", "model")})
    m = mesh_2pod()
    # decode_32k: batch 128 takes pod+data, cache_seq gets model
    s = rules.spec_for(("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim"), (48, 128, 32768, 8, 128), m)
    assert s == P(None, ("pod", "data"), "model", None, None)
    # long_500k: batch 1 unshardable, cache_seq takes everything
    s = rules.spec_for(("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim"), (48, 1, 524288, 8, 128), m)
    assert s == P(None, None, ("pod", "data", "model"), None, None)


def test_fsdp_profile():
    rules = default_rules({"embed": ("data",)})
    s = rules.spec_for(("layers", "embed", "mlp"), (36, 2048, 11008),
                       mesh_1pod())
    assert s == P(None, "data", "model")
    # activations: batch wins the data axis, embed then replicates
    s = rules.spec_for(("batch", "seq", "embed"), (256, 4096, 2048),
                       mesh_1pod())
    assert s == P("data", None, None)


AXES_POOL = ["batch", "seq", "embed", "heads", "kv_heads", "head_dim",
             "mlp", "vocab", "experts", "layers", "cache_seq", None]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(AXES_POOL),
                          st.integers(1, 4096)), min_size=1, max_size=5))
def test_no_mesh_axis_used_twice(dims):
    """PartitionSpec invariant: a mesh axis may appear at most once."""
    axes = tuple(a for a, _ in dims)
    shape = tuple(s for _, s in dims)
    s = RULES.spec_for(axes, shape, mesh_2pod())
    flat = []
    for part in s:
        if part is None:
            continue
        if isinstance(part, tuple):
            flat.extend(part)
        else:
            flat.append(part)
    assert len(flat) == len(set(flat)), (axes, shape, s)
    # and every sharded dim divides evenly
    m = mesh_2pod()
    for part, size in zip(s, shape):
        if part is None:
            continue
        total = 1
        for ax in (part if isinstance(part, tuple) else (part,)):
            total *= m.shape[ax]
        assert size % total == 0, (axes, shape, s)
