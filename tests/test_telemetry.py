"""Telemetry layer (observability PR): zero-overhead-when-off contract.

The load-bearing property: instrumentation NEVER changes the trajectory.
Telemetry-on runs must reproduce the frozen 60-job goldens and the
1000-job sha256 traces bit-for-bit, both engines must roll up to bitwise
equal utilization, and the offline ``metrics_rollup`` replay of a
recorded event stream must equal the live accumulation exactly.
"""
import json
import math

import pytest

from test_placement import (FRAG, GOLDEN_1000JOB_SHA256,
                            GOLDEN_60JOB_JCT_HOURS, _trace_sha256)

from repro.collectives.cost import ClusterModel
from repro.core import scheduler as S
from repro.core import telemetry as tele
from repro.core.jobs import make_workload, synthetic_workload
from repro.core.simulator import simulate


@pytest.fixture(scope="module")
def trace60():
    return synthetic_workload(60, 500.0, 0)


def _run(jobs, strat="precompute", cluster=None, sink=None, **kw):
    return simulate(jobs, 64 if cluster is None else None, strat,
                    cluster=cluster, telemetry=tele.Telemetry(sink=sink),
                    **kw)


# --------------------------------------------------------------------------
# Trajectory invariance: telemetry on == telemetry off, bit for bit
# --------------------------------------------------------------------------


@pytest.mark.parametrize("strat", sorted(GOLDEN_60JOB_JCT_HOURS))
def test_60job_goldens_hold_with_telemetry_on(trace60, strat):
    res = _run(trace60, strat, sink=tele.MemorySink())
    assert res.avg_jct_hours == GOLDEN_60JOB_JCT_HOURS[strat], strat


@pytest.mark.parametrize("pattern", sorted(GOLDEN_1000JOB_SHA256))
def test_1000job_sha256_holds_with_telemetry_on(pattern):
    jobs = make_workload(pattern, 1000, 250.0, 0)
    res = _run(jobs, "precompute", sink=tele.RingSink(4096))
    assert _trace_sha256(res) == GOLDEN_1000JOB_SHA256[pattern], pattern


def test_every_policy_on_off_parity_and_utilization(trace60):
    for strat in S.registered_policies().values():
        off = simulate(trace60, 64, strat)
        on = _run(trace60, strat, sink=tele.MemorySink())
        assert off.completion_times == on.completion_times, strat
        assert off.telemetry is None and off.utilization is None, strat
        assert on.telemetry is not None, strat
        assert 0.0 < on.utilization <= 1.0, strat


def test_cross_engine_rollup_is_bitwise_equal(trace60):
    """Both engines see the same per-timestamp event sets, so the
    time-weighted integrals must agree to the last bit — on the flat
    cluster and under fragmentation/migration alike."""
    for cluster in (None, FRAG):
        for strat in ("precompute", "srtf"):
            fast = _run(trace60, strat, cluster=cluster)
            ref = _run(trace60, strat, cluster=cluster, engine="reference")
            a, b = fast.telemetry, ref.telemetry
            assert a.utilization == b.utilization, (strat, cluster)
            assert a.busy_gpu_seconds == b.busy_gpu_seconds, (strat, cluster)
            assert a.queue_peak == b.queue_peak, (strat, cluster)
            assert a.queue_mean == b.queue_mean, (strat, cluster)
            assert a.avg_jct_s == b.avg_jct_s, (strat, cluster)
            assert a.jct_histogram == b.jct_histogram, (strat, cluster)


def test_rollup_agrees_with_simresult(trace60):
    res = _run(trace60, "precompute", sink=tele.MemorySink())
    t = res.telemetry
    # np.mean is pairwise, the recorder sums serially: isclose, not ==
    assert math.isclose(t.avg_jct_s / 3600.0, res.avg_jct_hours)
    assert t.n_completed == len(res.completion_times)
    assert t.n_rejected == len(res.rejected)
    assert t.n_migrations == res.migrations
    roll = t.rollup()
    json.dumps(roll)            # JSON-serializable by construction
    assert roll["utilization"] == t.utilization
    assert roll["counters"] == t.counters


# --------------------------------------------------------------------------
# Event stream: every kind shows up where it should, schema-valid
# --------------------------------------------------------------------------


def _kinds(events):
    return {e["kind"] for e in events}


def test_flat_run_emits_core_lifecycle_kinds(trace60):
    sink = tele.MemorySink()
    _run(trace60, "precompute", sink=sink)
    evs = sink.events
    for ev in evs:
        tele.validate_event(ev)
    assert evs[0]["kind"] == "run" and evs[-1]["kind"] == "end"
    assert {"submit", "admit", "alloc", "freeze", "unfreeze", "complete",
            "solve"} <= _kinds(evs)
    # solve records are fresh solves only; reuses live in the counters
    n_solve = sum(1 for e in evs if e["kind"] == "solve")
    ctrs = _run(trace60, "precompute").telemetry.counters
    assert n_solve == ctrs["solve.calls"] - ctrs["solve.reused"]
    assert all(not e["reuse"] for e in evs if e["kind"] == "solve")


def test_reject_events_on_queue_cap_cluster():
    cl = ClusterModel(capacity=16, gpus_per_node=8,
                      inter_node_beta=1.0 / 1.25e8, placement="packed",
                      admission="queue_cap_2")
    jobs = make_workload("bursty", 60, 100.0, 3)
    sink = tele.MemorySink()
    res = _run(jobs, "precompute", cluster=cl, sink=sink)
    rejects = [e for e in sink.events if e["kind"] == "reject"]
    assert len(rejects) == len(res.rejected) > 0
    assert {e["job"] for e in rejects} == set(res.rejected)


def test_delay_events_on_free_gpus_cluster():
    cl = ClusterModel(capacity=16, gpus_per_node=8,
                      inter_node_beta=1.0 / 1.25e8, placement="packed",
                      admission="free_gpus_4")
    jobs = make_workload("bursty", 60, 100.0, 3)
    sink = tele.MemorySink()
    _run(jobs, "precompute", cluster=cl, sink=sink)
    assert any(e["kind"] == "delay" for e in sink.events)


def test_migrate_events_match_migration_count():
    jobs = make_workload("mixed_maxw", 40, 300.0, 7)
    sink = tele.MemorySink()
    res = _run(jobs, "precompute", cluster=FRAG, sink=sink)
    migs = [e for e in sink.events if e["kind"] == "migrate"]
    assert res.migrations > 0, "scenario no longer migrates — pick another"
    assert len(migs) == res.migrations == res.telemetry.n_migrations


def test_unfreeze_follows_freeze_in_order(trace60):
    sink = tele.MemorySink()
    _run(trace60, "srtf", sink=sink)
    frozen = {}
    for ev in sink.events:
        if ev["kind"] == "freeze":
            frozen[ev["job"]] = ev["until"]
        elif ev["kind"] == "unfreeze":
            assert ev["job"] in frozen, "unfreeze without freeze"
            assert ev["t"] == frozen.pop(ev["job"])
    # stream is time-ordered (lazy unfreeze flushing must not reorder)
    ts = [e["t"] for e in sink.events]
    assert ts == sorted(ts)


def test_validate_event_rejects_malformed():
    with pytest.raises(ValueError, match="unknown event kind"):
        tele.validate_event({"kind": "nope", "t": 0.0})
    with pytest.raises(ValueError, match="missing field"):
        tele.validate_event({"kind": "admit", "t": 0.0})
    with pytest.raises(ValueError, match="type"):
        tele.validate_event({"kind": "admit", "t": 0.0, "job": "seven"})
    with pytest.raises(ValueError, match="type"):
        # bools are not ints/floats for schema purposes
        tele.validate_event({"kind": "admit", "t": True, "job": 7})
    # float fields accept ints (JSON number), extras are allowed
    tele.validate_event({"kind": "admit", "t": 3, "job": 7, "extra": "ok"})


# --------------------------------------------------------------------------
# Sinks
# --------------------------------------------------------------------------


def test_ring_sink_is_bounded(trace60):
    sink = tele.RingSink(maxlen=64)
    _run(trace60, "precompute", sink=sink)
    assert len(sink.events) == 64
    assert sink.events[-1]["kind"] == "end"


def test_jsonl_round_trip_and_offline_rollup(tmp_path, trace60):
    path = str(tmp_path / "events.jsonl")
    res = _run(trace60, "precompute", sink=tele.JSONLSink(path))
    events = tele.read_jsonl(path)
    for ev in events:
        tele.validate_event(ev)
    live = res.telemetry
    replay = tele.metrics_rollup(events)
    assert replay.utilization == live.utilization
    assert replay.queue_mean == live.queue_mean
    assert replay.queue_peak == live.queue_peak
    assert replay.jct_histogram == live.jct_histogram
    assert replay.n_completed == live.n_completed


def test_tee_sink_fans_out(trace60):
    a, b = tele.MemorySink(), tele.RingSink(maxlen=16)
    _run(trace60, "precompute", sink=tele.TeeSink([a, b]))
    assert len(a.events) > 16 and len(b.events) == 16
    assert a.events[-16:] == list(b.events)


def test_chrome_trace_is_perfetto_loadable(tmp_path):
    """The acceptance smoke test: json.load the file, every event carries
    ph/ts/pid, and there are complete ("X") spans on per-node tracks."""
    path = str(tmp_path / "trace.json")
    cl = ClusterModel(capacity=32, gpus_per_node=8,
                      inter_node_beta=1.0 / 1.25e8)
    jobs = make_workload("poisson", 40, 300.0, 0)
    _run(jobs, "precompute", cluster=cl, sink=tele.ChromeTraceSink(path))
    with open(path) as fh:
        doc = json.load(fh)
    evs = doc["traceEvents"]
    assert evs, "empty trace"
    for ev in evs:
        assert "ph" in ev and "ts" in ev and "pid" in ev
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all(e["dur"] >= 0 for e in spans)
    # slots map to node-level tracks: pid = node index
    assert {e["pid"] for e in spans} <= set(range(4))


def test_write_chrome_trace_from_memory_events(tmp_path, trace60):
    sink = tele.MemorySink()
    _run(trace60, "precompute", sink=sink)
    path = str(tmp_path / "post.json")
    tele.write_chrome_trace(path, sink.events)
    with open(path) as fh:
        doc = json.load(fh)
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


# --------------------------------------------------------------------------
# Registry / disabled path
# --------------------------------------------------------------------------


def test_registry_counters_and_timers():
    reg = tele.Registry()
    c = reg.counter("x")
    assert c is reg.counter("x")        # memoized handle
    c.inc()
    c.inc(4)
    t = reg.timer("y")
    t.add(0.5)
    t.add(0.25)
    assert reg.counters() == {"x": 5}
    assert t.total_s == 0.75 and t.count == 2


def test_null_singletons_are_inert():
    tele.NULL_COUNTER.inc(100)
    tele.NULL_TIMER.add(1.0)
    rec = tele.NULL_RECORDER
    assert rec.on is False
    rec.submit(0.0, 1, 0.0)
    rec.solve(0.0, 0, True, 0)
    rec.solve_reused()
    assert rec.finish(1.0) is None
    r = tele.NULL.recorder("p", 64, 10)
    assert r is tele.NULL_RECORDER


def test_decision_counters_present_per_policy(trace60):
    res = _run(trace60, "srtf")
    ctrs = res.telemetry.counters
    assert ctrs["solve.calls"] > 0
    assert 0 < ctrs["solve.reused"] <= ctrs["solve.calls"]
    assert ctrs["heap.pushes"] == ctrs["heap.pops"] >= 0
    assert res.telemetry.timers["solve.wall_s"]["count"] > 0


def test_shared_registry_accumulates_across_runs(trace60):
    reg = tele.Registry()
    handle = tele.Telemetry(registry=reg)
    one = simulate(trace60, 64, "precompute", telemetry=handle)
    solo = one.telemetry.counters["solve.calls"]
    simulate(trace60, 64, "precompute", telemetry=handle)
    assert reg.counters()["solve.calls"] == 2 * solo
