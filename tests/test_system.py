"""End-to-end behaviour tests: the paper's full story on one process —
profile -> fit models -> schedule -> elastic stop/restart -> faster finish."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs.resnet110 import smoke_config
from repro.core import scheduler as S
from repro.core.convergence import fit_convergence
from repro.core.elastic import ElasticTrainer
from repro.core.jobs import JobSpec
from repro.core.resource_model import fit_resource_model
from repro.data.synthetic import CifarLike
from repro.models.resnet import ResNetModel
from repro.optim.optimizers import sgd

# Full training loops + CLI subprocesses: minutes, not seconds — keep the
# whole module out of the fast CI lane.
pytestmark = pytest.mark.slow


def test_paper_pipeline_end_to_end():
    """(1) train and log losses; (2) fit eq.(1) to predict remaining work;
    (3) fit eq.(5) from step-time observations; (4) scheduler doubles the
    job; (5) elastic restart at 2x workers continues training."""
    cfg = smoke_config()
    model = ResNetModel(cfg)
    data = CifarLike(size=512, seed=0)
    with tempfile.TemporaryDirectory() as d:
        tr = ElasticTrainer(model, sgd(), data, CheckpointStore(d),
                            base_lr_1w=0.05, m_per_worker=16,
                            dataset_size=512)
        rec = tr.train_segment(w=1, n_steps=30, resume=False, log_every=1)

        # (2) convergence model on the observed curve
        steps = np.array([s for s, _, _ in rec.losses], float)
        losses = np.array([l for _, _, l in rec.losses], float)
        conv = fit_convergence(steps, losses)
        assert np.isfinite(conv.loss_at(100.0))

        # (3) resource model from synthetic profile points (Table-1 style)
        ws = np.array([1, 2, 4, 8])
        spec = JobSpec(0, 0.0, 160.0, speed_mode="analytic")
        speeds = np.array([spec.speed(int(w)) for w in ws])
        rm = fit_resource_model(ws, speeds, m=128, n=6.9e6)
        assert np.all(np.diff(rm.f(ws)) > 0)

        # (4) schedule: single job, ample capacity -> doubling grows it
        jobs = [(0, 100.0, lambda w: float(rm.f(np.array([w]))[0]))]
        alloc = S.doubling_heuristic(jobs, capacity=8, max_w=8)
        assert alloc[0] == 8

        # (5) elastic restart at the scheduler's allocation
        rec2 = tr.train_segment(w=alloc[0], n_steps=10, resume=True,
                                log_every=2)
        assert rec2.epochs > rec.epochs
        assert rec2.losses[-1][2] < rec.losses[0][2]


def test_train_cli_loss_decreases():
    from repro.launch.train import main
    first, last = main(["--arch", "gemma-2b", "--smoke", "--steps", "25",
                        "--workers", "2", "--m-per-worker", "4",
                        "--seq", "32", "--log-every", "25"])
    assert last < first - 0.15, (first, last)


def test_serve_cli_generates():
    from repro.configs import get_smoke_config
    from repro.launch.serve import serve
    gen, dt = serve(get_smoke_config("qwen2.5-3b"), batch=2, prompt_len=8,
                    new_tokens=4, log=False)
    assert gen.shape == (2, 4)
    assert gen.dtype == np.int32


def test_microbatch_equivalence():
    """Gradient accumulation (k microbatches) must match the single-batch
    step up to float association order."""
    from repro.configs import get_smoke_config
    from repro.engine.steps import make_train_step, init_train_state
    from repro.models.registry import build_model
    from repro.optim.optimizers import sgd

    cfg = get_smoke_config("gemma-2b")
    model = build_model(cfg)
    opt = sgd(momentum=0.0, weight_decay=0.0)
    state = init_train_state(model, opt)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    st1, l1 = s1(state, batch, jnp.float32(0.1))
    st4, l4 = s4(state, batch, jnp.float32(0.1))
    assert abs(float(l1) - float(l4)) < 5e-3
    for a, b in zip(jax.tree_util.tree_leaves(st1["params"]),
                    jax.tree_util.tree_leaves(st4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)
