"""Decode-vs-forward consistency: teacher-forced forward logits must match
step-by-step KV-cache/SSM-state decode logits — the strongest correctness
check for every cache implementation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.shapes import InputShape
from repro.models import spec as pspec
from repro.models.registry import build_model

S = 24


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma-2b", "mamba2-780m",
                                  "jamba-v0.1-52b", "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=None)
    if cfg.is_moe:
        # ample capacity: capacity-MoE drops tokens in teacher-forced mode
        # but never at single-token decode (inherent train/serve skew); this
        # test targets the cache logic, not the drop policy.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)

    fwd_logits, _ = model.forward(params, {"tokens": tokens})

    dshape = InputShape("d", S, 2, "decode")
    cache = pspec.init_params(jax.random.PRNGKey(1),
                              model.cache_specs(dshape))
    decode = jax.jit(model.decode_step)
    step_logits = []
    for t in range(S):
        batch = {"tokens": tokens[:, t:t + 1],
                 "pos": jnp.full((2,), t, jnp.int32)}
        logits, cache = decode(params, cache, batch)
        step_logits.append(logits[:, 0])
    got = jnp.stack(step_logits, axis=1)

    # bf16 activations; compare in relative terms on the logits
    err = float(jnp.max(jnp.abs(got - fwd_logits)))
    scale = float(jnp.max(jnp.abs(fwd_logits))) + 1e-6
    assert err / scale < 0.08, (arch, err, scale)
    # argmax agreement is the serving-level contract (hybrid stacks more
    # bf16 noise through mamba+moe layers; its exact check is the f32 test)
    agree = float(jnp.mean((jnp.argmax(got, -1)
                            == jnp.argmax(fwd_logits, -1)).astype(
                                jnp.float32)))
    floor = 0.9 if cfg.family == "hybrid" else 0.95
    assert agree > floor, (arch, agree)


def test_jamba_decode_exact_in_f32(monkeypatch):
    """With f32 activations and caches, hybrid decode must match the
    teacher-forced forward to ~1e-5 — proves the cache logic is exact and
    the bf16 disagreement above is pure rounding."""
    import dataclasses
    import repro.models.layers as L

    def embed_f32(embedding, tokens, scale=None):
        x = jnp.take(embedding, tokens, axis=0).astype(jnp.float32)
        return x * scale if scale is not None else x

    monkeypatch.setattr(L, "embed_tokens", embed_f32)
    cfg = dataclasses.replace(get_smoke_config("jamba-v0.1-52b"),
                              capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    fwd, _ = model.forward(params, {"tokens": tokens})
    dshape = InputShape("d", S, 2, "decode")
    cache = pspec.init_params(jax.random.PRNGKey(1),
                              model.cache_specs(dshape))
    cache = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), cache)
    decode = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = decode(params, cache,
                               {"tokens": tokens[:, t:t + 1],
                                "pos": jnp.full((2,), t, jnp.int32)})
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(got - fwd))) < 1e-4


def test_whisper_decode_matches_forward():
    cfg = get_smoke_config("whisper-base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(2, cfg.n_frontend_tokens,
                                          cfg.d_model)) * 0.1, jnp.bfloat16)

    fwd_logits, _ = model.forward(params, {"tokens": tokens,
                                           "frames": frames})
    enc = model.encode(params, frames)

    dshape = InputShape("d", S, 2, "decode")
    cache = pspec.init_params(jax.random.PRNGKey(1),
                              model.cache_specs(dshape))
    cache["enc"] = enc.astype(cache["enc"].dtype)
    decode = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        batch = {"tokens": tokens[:, t:t + 1],
                 "pos": jnp.full((2,), t, jnp.int32)}
        logits, cache = decode(params, cache, batch)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(got - fwd_logits)))
    scale = float(jnp.max(jnp.abs(fwd_logits))) + 1e-6
    assert err / scale < 0.08, (err, scale)


def test_sliding_window_decode_matches_forward():
    """SWA (danube-style) forward/decode agreement with the window active."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    assert cfg.sliding_window
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    fwd_logits, _ = model.forward(params, {"tokens": tokens})
    dshape = InputShape("d", S, 2, "decode")
    cache = pspec.init_params(jax.random.PRNGKey(1),
                              model.cache_specs(dshape))
    decode = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        batch = {"tokens": tokens[:, t:t + 1],
                 "pos": jnp.full((2,), t, jnp.int32)}
        logits, cache = decode(params, cache, batch)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(got - fwd_logits)))
    scale = float(jnp.max(jnp.abs(fwd_logits))) + 1e-6
    assert err / scale < 0.08, (err, scale)
