"""Incremental cross-tick scheduling core (ISSUE 5).

Three layers of gates:

  * persistent gain-heap / remaining-time-heap identity: random
    arrival/run/freeze/completion sequences driven through the real
    ``_SoAState`` + ``IncrementalContext`` spine, asserting at *every*
    tick that the incremental solve equals a fresh solve over the same
    views (hypothesis property + a deterministic fuzz twin that runs
    even without hypothesis installed);
  * speed-table row interning: identical jobs share one table array
    object and one ``_SoAState`` row id, distinct hardware does not;
  * the engine's supporting structures: calendar-queue order matches a
    binary heap, and windowed removal preserves order and the
    seq->position map on every path (head block, head shift, tail
    shift, batch).
"""
import dataclasses

import heapq

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as hst

from repro.collectives.cost import ClusterModel, INFINIBAND_100G, TPU_V5E
from repro.core import scheduler as sched
from repro.core.jobs import JobSpec
from repro.core.simulator import _CalendarQueue, _SoAState

CAPACITY = 16


def _fresh_view(view: sched.AllocView) -> sched.AllocView:
    """The same SoA views with the cross-tick spine stripped — forces
    every policy down its fresh-solve path (the reference-oracle shape)."""
    return dataclasses.replace(view, seq=None, inc=None)


class _Harness:
    """Drives one policy's incremental solver through an arbitrary
    arrival/run/freeze/completion sequence over a real ``_SoAState``,
    checking allocation identity with a fresh-heap solve at every tick.

    Between ticks only jobs the *incremental* solve granted workers may
    advance (exactly the engine's contract: w=0 and frozen jobs make no
    progress), and a "freeze" is modeled faithfully as a granted job
    whose remaining work does not move.
    """

    def __init__(self, spec: str, seed: int):
        self.policy = sched.get_policy(spec)
        self.cluster = ClusterModel(capacity=CAPACITY)
        self.st = _SoAState(table_width=CAPACITY + 1)
        self.rng = np.random.default_rng(seed)
        self.n_added = 0
        self.target = np.zeros(0, np.int64)

    def solve_and_check(self) -> None:
        view = self.st.view()
        inc = self.policy.allocate(view, self.cluster, 0.0)
        fresh = self.policy.allocate(_fresh_view(view), self.cluster, 0.0)
        assert np.array_equal(inc, fresh), (
            f"{self.policy.spec}: incremental {inc.tolist()} != "
            f"fresh {fresh.tolist()} at n={self.st.n}")
        self.target = inc

    def arrive(self, epochs: float, max_w: int) -> None:
        spec = JobSpec(job_id=self.n_added, arrival=0.0, epochs=epochs,
                       max_w=max_w)
        self.n_added += 1
        self.st.add(spec, spec.speed_table(self.cluster), None)

    def run_some(self, fractions) -> None:
        """Advance a subset of the granted jobs (ungranted/frozen jobs
        keep their remaining work — the incremental heaps must treat
        them as clean)."""
        st = self.st
        granted = np.nonzero(self.target > 0)[0]
        for k, frac in zip(granted, fractions):
            if frac > 0.0:
                i = st.start + int(k)
                st.remaining[i] = max(st.remaining[i] * (1.0 - frac), 1e-6)

    def complete(self, which: int) -> None:
        st = self.st
        if st.n == 0:
            return
        st.remove([st.start + (which % st.n)])

    def step(self, op) -> None:
        kind = op[0]
        if kind == "arrive":
            self.arrive(op[1], op[2])
        elif kind == "run":
            self.run_some(op[1])
        else:
            self.complete(op[1])
        if self.st.n:
            self.solve_and_check()


INCREMENTAL_SPECS = ("precompute", "optimus", "srtf", "pack_srtf")


def _op_strategy():
    arrive = hst.tuples(hst.just("arrive"),
                        hst.floats(min_value=1.0, max_value=500.0,
                                   allow_nan=False),
                        hst.sampled_from([1, 2, 4, 8, 16, 64]))
    run = hst.tuples(hst.just("run"),
                     hst.lists(hst.floats(min_value=0.0, max_value=0.9),
                               min_size=0, max_size=CAPACITY))
    complete = hst.tuples(hst.just("complete"),
                          hst.integers(min_value=0, max_value=10 ** 6))
    return hst.lists(arrive | run | complete, min_size=1, max_size=60)


@settings(max_examples=80, deadline=None)
@given(spec=hst.sampled_from(INCREMENTAL_SPECS), ops=_op_strategy(),
       seed=hst.integers(min_value=0, max_value=2 ** 16))
def test_incremental_equals_fresh_property(spec, ops, seed):
    """Any arrival/run/freeze/completion sequence: the persistent-heap
    solve is allocation-identical to a fresh-heap solve at every tick."""
    h = _Harness(spec, seed)
    for op in ops:
        h.step(op)


@pytest.mark.parametrize("spec", INCREMENTAL_SPECS)
def test_incremental_equals_fresh_fuzz(spec):
    """Deterministic fuzz twin of the property test (runs without
    hypothesis): 2000 random ticks per policy."""
    rng = np.random.default_rng(hash(spec) % 2 ** 31)
    h = _Harness(spec, 7)
    for _ in range(2000):
        r = rng.random()
        if r < 0.45 or h.st.n == 0:
            h.step(("arrive", float(rng.uniform(1.0, 500.0)),
                    int(rng.choice([1, 2, 4, 8, 16, 64]))))
        elif r < 0.8:
            h.step(("run", rng.uniform(0.0, 0.9,
                                       size=rng.integers(0, CAPACITY))))
        else:
            h.step(("complete", int(rng.integers(0, 10 ** 6))))


def test_incremental_survives_deep_queues():
    """More jobs than capacity: queued (w=0) jobs are clean across ticks
    and the prefix rotates as head jobs complete — the regime the
    persistent heaps exist for."""
    for spec in INCREMENTAL_SPECS:
        h = _Harness(spec, 3)
        for j in range(4 * CAPACITY):
            h.arrive(100.0 + j, 8)
        h.solve_and_check()
        for _ in range(3 * CAPACITY):
            h.run_some(np.full(CAPACITY, 0.5))
            h.solve_and_check()
            h.complete(0)           # head completion: window advances
            if h.st.n:
                h.solve_and_check()


# --------------------------------------------------------------------------
# Row interning.
# --------------------------------------------------------------------------

def test_identical_jobs_share_speed_table_object():
    a = JobSpec(job_id=0, arrival=0.0, epochs=100.0)
    b = JobSpec(job_id=1, arrival=50.0, epochs=200.0)
    assert a.speed_table(64) is b.speed_table(64)
    cluster = ClusterModel(capacity=64, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e9)
    assert a.speed_table(cluster) is b.speed_table(cluster)


def test_distinct_hardware_gets_distinct_tables():
    a = JobSpec(job_id=0, arrival=0.0, epochs=100.0, hw=INFINIBAND_100G)
    b = JobSpec(job_id=1, arrival=0.0, epochs=100.0, hw=TPU_V5E)
    assert a.speed_table(64) is not b.speed_table(64)
    assert not np.array_equal(a.speed_table(64), b.speed_table(64))


def test_soa_state_interns_rows():
    """Two jobs with identical (hw, placement, max_w) share one table
    row id; a different hardware preset gets its own row."""
    cluster = ClusterModel(capacity=16)
    st = _SoAState(table_width=17)
    a = JobSpec(job_id=0, arrival=0.0, epochs=100.0)
    b = JobSpec(job_id=1, arrival=1.0, epochs=250.0)  # size-only difference
    c = JobSpec(job_id=2, arrival=2.0, epochs=100.0, hw=TPU_V5E)
    for s in (a, b, c):
        st.add(s, s.speed_table(cluster), None)
    assert st.rows[0] == st.rows[1]
    assert st.rows[2] != st.rows[0]
    assert st.n_rows == 2
    # max_w does not change the table row (rows are capacity-wide); the
    # cap lives in the max_w column the solvers consult
    d = JobSpec(job_id=3, arrival=3.0, epochs=100.0, max_w=2)
    st.add(d, d.speed_table(cluster), None)
    assert st.rows[3] == st.rows[0]
    assert st.max_w[3] == 2


# --------------------------------------------------------------------------
# Calendar queue vs binary heap.
# --------------------------------------------------------------------------

def test_calendar_queue_matches_heapq():
    """The calendar queue pops in exactly heapq's (t, kind) order under
    the engine's usage pattern (pushes never land before the last pop)."""
    rng = np.random.default_rng(11)
    cq = _CalendarQueue(150.0)
    heap: list[tuple[float, int]] = []
    now = 0.0
    for _ in range(3000):
        if heap and rng.random() < 0.45:
            want = heapq.heappop(heap)
            got = cq.pop()
            assert got == want
            now = want[0]
        else:
            # near-future events, tick- and unfreeze-shaped
            t = now + float(rng.choice([0.0, 10.0, 150.0, 150.0, 437.5]))
            kind = int(rng.integers(0, 2))
            heapq.heappush(heap, (t, kind))
            cq.push(t, kind)
    while heap:
        assert cq.pop() == heapq.heappop(heap)
    assert cq.peek() is None


# --------------------------------------------------------------------------
# Windowed removal.
# --------------------------------------------------------------------------

def _fill(n):
    st = _SoAState(table_width=17)
    cluster = ClusterModel(capacity=16)
    for j in range(n):
        st.add(JobSpec(job_id=j, arrival=float(j), epochs=100.0 + j),
               JobSpec(job_id=j, arrival=0.0,
                       epochs=1.0).speed_table(cluster), None)
    return st


def _live_ids(st):
    return st.ids[st.start:st.start + st.n].tolist()


def _check_pos(st):
    for rel in range(st.n):
        i = st.start + rel
        assert st.pos_of_seq[st.seq[i]] == i


@pytest.mark.parametrize("gone_rel, want", [
    ([0], [1, 2, 3, 4, 5, 6, 7]),            # head -> window advance
    ([0, 1, 2], [3, 4, 5, 6, 7]),            # head block
    ([1], [0, 2, 3, 4, 5, 6, 7]),            # near head -> right shift
    ([6], [0, 1, 2, 3, 4, 5, 7]),            # near tail -> left shift
    ([7], [0, 1, 2, 3, 4, 5, 6]),            # tail
    ([1, 4, 6], [0, 2, 3, 5, 7]),            # batch
    ([0, 1, 2, 3, 4, 5, 6, 7], []),          # everything
])
def test_remove_preserves_order_and_positions(gone_rel, want):
    st = _fill(8)
    st.remove([st.start + g for g in gone_rel])
    assert _live_ids(st) == want
    _check_pos(st)


def test_remove_fuzz_against_list_model():
    rng = np.random.default_rng(5)
    st = _fill(40)
    model = list(range(40))
    next_id = 40
    cluster = ClusterModel(capacity=16)
    row = JobSpec(job_id=0, arrival=0.0, epochs=1.0).speed_table(cluster)
    for _ in range(300):
        if model and rng.random() < 0.55:
            k = int(rng.integers(1, min(4, len(model)) + 1))
            rel = sorted(rng.choice(len(model), size=k, replace=False))
            st.remove([st.start + int(r) for r in rel])
            for r in reversed(rel):
                del model[int(r)]
        else:
            st.add(JobSpec(job_id=next_id, arrival=0.0, epochs=50.0),
                   row, None)
            model.append(next_id)
            next_id += 1
        assert _live_ids(st) == model
        _check_pos(st)
