"""Incremental cross-tick scheduling core (ISSUE 5) and the sparse
allocation-delta contract (ISSUE 8).

Three layers of gates:

  * delta-vs-dense identity across **all registered policies**: random
    arrival/run/freeze/completion sequences driven through the real
    slot-stable ``_SoAState`` + ``IncrementalContext`` spine, asserting
    at *every* tick that the slotted solve's delta, applied to the
    engine-held allocation, equals a fresh dense-target solve over the
    same live set (hypothesis property + a deterministic fuzz twin that
    runs even without hypothesis installed);
  * speed-table row interning: identical jobs share one table array
    object and one ``_SoAState`` row id, distinct hardware does not;
  * the engine's supporting structures: calendar-queue order matches a
    binary heap, and slot-stable removal preserves live order, the
    next-live pointer chain, and the FIFO prefix cache on every path
    (head, interior, tail, batch).
"""
import heapq

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as hst

from repro.collectives.cost import ClusterModel, INFINIBAND_100G, TPU_V5E
from repro.core import scheduler as sched
from repro.core.jobs import JobSpec
from repro.core.simulator import _CalendarQueue, _SoAState

CAPACITY = 16


class _Harness:
    """Drives one policy through an arbitrary arrival/run/freeze/
    completion sequence over a real slot-stable ``_SoAState``, playing
    the engine's role: slotted policies' sparse deltas are applied to
    the held ``w`` array and the result is checked against a fresh
    dense-target solve over the gathered live set at every tick.

    Between ticks only jobs the *applied* allocation granted workers may
    advance (exactly the engine's contract: w=0 and frozen jobs make no
    progress), and a "freeze" is modeled faithfully as a granted job
    whose remaining work does not move.  The clock advances so
    exploratory's segment schedule (and its persistent cursor) is
    exercised too.
    """

    def __init__(self, spec: str, seed: int):
        self.policy = sched.get_policy(spec)
        self.cluster = ClusterModel(capacity=CAPACITY)
        self.st = _SoAState(table_width=CAPACITY + 1)
        self.rng = np.random.default_rng(seed)
        self.n_added = 0
        self.now = 0.0

    def solve_and_check(self) -> None:
        st = self.st
        ls = st.live_slots()
        res = self.policy.allocate(st.view(), self.cluster, self.now)
        applied = st.w[ls].copy()
        if self.policy.slotted:
            assert isinstance(res, sched.AllocDelta), (
                f"{self.policy.spec}: slotted policies must return "
                f"AllocDelta, got {type(res).__name__}")
            if len(res.slots):
                assert st.alive[res.slots].all(), (
                    f"{self.policy.spec}: delta names a dead slot")
                applied[np.searchsorted(ls, res.slots)] = res.w
        else:
            applied = np.asarray(res)
        fresh = self.policy.allocate(st.dense_view(ls), self.cluster,
                                     self.now)
        assert np.array_equal(applied, fresh), (
            f"{self.policy.spec}: delta-applied {applied.tolist()} != "
            f"fresh dense {fresh.tolist()} at n={st.n} now={self.now}")
        st.w[ls] = applied

    def arrive(self, epochs: float, max_w: int) -> None:
        spec = JobSpec(job_id=self.n_added, arrival=0.0, epochs=epochs,
                       max_w=max_w)
        self.n_added += 1
        self.st.add(spec, spec.speed_table(self.cluster),
                    self.now if self.policy.explores else None)

    def run_some(self, fractions, dt: float) -> None:
        """Advance the clock and a subset of the granted jobs
        (ungranted/frozen jobs keep their remaining work — the
        incremental heaps must treat them as clean)."""
        self.now += dt
        st = self.st
        granted = st.live_slots()
        granted = granted[st.w[granted] > 0]
        for s, frac in zip(granted.tolist(), fractions):
            if frac > 0.0:
                st.remaining[s] = max(st.remaining[s] * (1.0 - frac), 1e-6)

    def complete(self, which: int) -> None:
        st = self.st
        if st.n == 0:
            return
        ls = st.live_slots()
        st.remove([int(ls[which % len(ls)])])

    def step(self, op) -> None:
        kind = op[0]
        if kind == "arrive":
            self.arrive(op[1], op[2])
        elif kind == "run":
            self.run_some(op[1], op[2])
        else:
            self.complete(op[1])
        if self.st.n:
            self.solve_and_check()


# Every registered policy rides the harness — the sparse-delta contract
# is registry-wide, not a per-policy opt-in to the tests.
INCREMENTAL_SPECS = tuple(sched.registered_policies().values())


def _op_strategy():
    arrive = hst.tuples(hst.just("arrive"),
                        hst.floats(min_value=1.0, max_value=500.0,
                                   allow_nan=False),
                        hst.sampled_from([1, 2, 4, 8, 16, 64]))
    run = hst.tuples(hst.just("run"),
                     hst.lists(hst.floats(min_value=0.0, max_value=0.9),
                               min_size=0, max_size=CAPACITY),
                     hst.floats(min_value=0.0, max_value=400.0,
                                allow_nan=False))
    complete = hst.tuples(hst.just("complete"),
                          hst.integers(min_value=0, max_value=10 ** 6))
    return hst.lists(arrive | run | complete, min_size=1, max_size=60)


@settings(max_examples=80, deadline=None)
@given(spec=hst.sampled_from(INCREMENTAL_SPECS), ops=_op_strategy(),
       seed=hst.integers(min_value=0, max_value=2 ** 16))
def test_delta_equals_dense_property(spec, ops, seed):
    """Any arrival/run/freeze/completion sequence: the slotted sparse
    delta, applied to the engine-held state, is allocation-identical to
    a fresh dense solve at every tick — for every registered policy."""
    h = _Harness(spec, seed)
    for op in ops:
        h.step(op)


@pytest.mark.parametrize("spec", INCREMENTAL_SPECS)
def test_delta_equals_dense_fuzz(spec):
    """Deterministic fuzz twin of the property test (runs without
    hypothesis): 2000 random ticks per policy."""
    rng = np.random.default_rng(hash(spec) % 2 ** 31)
    h = _Harness(spec, 7)
    for _ in range(2000):
        r = rng.random()
        if r < 0.45 or h.st.n == 0:
            h.step(("arrive", float(rng.uniform(1.0, 500.0)),
                    int(rng.choice([1, 2, 4, 8, 16, 64]))))
        elif r < 0.8:
            h.step(("run", rng.uniform(0.0, 0.9,
                                       size=rng.integers(0, CAPACITY)),
                    float(rng.uniform(0.0, 400.0))))
        else:
            h.step(("complete", int(rng.integers(0, 10 ** 6))))


def test_incremental_survives_deep_queues():
    """More jobs than capacity: queued (w=0) jobs are clean across ticks
    and the prefix rotates as head jobs complete — the regime the
    persistent heaps and the saturation shortcut exist for."""
    for spec in INCREMENTAL_SPECS:
        h = _Harness(spec, 3)
        for j in range(4 * CAPACITY):
            h.arrive(100.0 + j, 8)
        h.solve_and_check()
        for _ in range(3 * CAPACITY):
            h.run_some(np.full(CAPACITY, 0.5), 150.0)
            h.solve_and_check()
            h.complete(0)           # head completion: lo advances
            if h.st.n:
                h.solve_and_check()


def test_interior_completions_deep_queue():
    """Interior (non-head) completions against a deep queue — SRTF's
    adversarial shape for the old min-side shift; now O(1) per death
    plus prefix patching, and allocations must stay delta-exact while
    the prefix refills from the next-live chain."""
    for spec in INCREMENTAL_SPECS:
        h = _Harness(spec, 9)
        for j in range(4 * CAPACITY):
            h.arrive(50.0 + 3 * j, 8)
        h.solve_and_check()
        for k in range(3 * CAPACITY):
            h.run_some(np.full(CAPACITY, 0.3), 150.0)
            h.solve_and_check()
            h.complete(5 + (k % CAPACITY))      # mid-prefix death
            if h.st.n:
                h.solve_and_check()


# --------------------------------------------------------------------------
# Row interning.
# --------------------------------------------------------------------------

def test_identical_jobs_share_speed_table_object():
    a = JobSpec(job_id=0, arrival=0.0, epochs=100.0)
    b = JobSpec(job_id=1, arrival=50.0, epochs=200.0)
    assert a.speed_table(64) is b.speed_table(64)
    cluster = ClusterModel(capacity=64, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e9)
    assert a.speed_table(cluster) is b.speed_table(cluster)


def test_distinct_hardware_gets_distinct_tables():
    a = JobSpec(job_id=0, arrival=0.0, epochs=100.0, hw=INFINIBAND_100G)
    b = JobSpec(job_id=1, arrival=0.0, epochs=100.0, hw=TPU_V5E)
    assert a.speed_table(64) is not b.speed_table(64)
    assert not np.array_equal(a.speed_table(64), b.speed_table(64))


def test_soa_state_interns_rows():
    """Two jobs with identical (hw, placement, max_w) share one table
    row id; a different hardware preset gets its own row."""
    cluster = ClusterModel(capacity=16)
    st = _SoAState(table_width=17)
    a = JobSpec(job_id=0, arrival=0.0, epochs=100.0)
    b = JobSpec(job_id=1, arrival=1.0, epochs=250.0)  # size-only difference
    c = JobSpec(job_id=2, arrival=2.0, epochs=100.0, hw=TPU_V5E)
    for s in (a, b, c):
        st.add(s, s.speed_table(cluster), None)
    assert st.rows[0] == st.rows[1]
    assert st.rows[2] != st.rows[0]
    assert st.n_rows == 2
    # max_w does not change the table row (rows are capacity-wide); the
    # cap lives in the max_w column the solvers consult
    d = JobSpec(job_id=3, arrival=3.0, epochs=100.0, max_w=2)
    st.add(d, d.speed_table(cluster), None)
    assert st.rows[3] == st.rows[0]
    assert st.max_w[3] == 2


# --------------------------------------------------------------------------
# Calendar queue vs binary heap.
# --------------------------------------------------------------------------

def test_calendar_queue_matches_heapq():
    """The calendar queue pops in exactly heapq's (t, kind) order under
    the engine's usage pattern (pushes never land before the last pop)."""
    rng = np.random.default_rng(11)
    cq = _CalendarQueue(150.0)
    heap: list[tuple[float, int]] = []
    now = 0.0
    for _ in range(3000):
        if heap and rng.random() < 0.45:
            want = heapq.heappop(heap)
            got = cq.pop()
            assert got == want
            now = want[0]
        else:
            # near-future events, tick- and unfreeze-shaped
            t = now + float(rng.choice([0.0, 10.0, 150.0, 150.0, 437.5]))
            kind = int(rng.integers(0, 2))
            heapq.heappush(heap, (t, kind))
            cq.push(t, kind)
    while heap:
        assert cq.pop() == heapq.heappop(heap)
    assert cq.peek() is None


# --------------------------------------------------------------------------
# Slot-stable removal: live order, next-live chain, FIFO prefix cache.
# --------------------------------------------------------------------------

def _fill(n):
    st = _SoAState(table_width=17)
    cluster = ClusterModel(capacity=16)
    for j in range(n):
        st.add(JobSpec(job_id=j, arrival=float(j), epochs=100.0 + j),
               JobSpec(job_id=j, arrival=0.0,
                       epochs=1.0).speed_table(cluster), None)
    return st


def _live_ids(st):
    return st.ids[st.live_slots()].tolist()


def _check_invariants(st):
    ls = st.live_slots()
    assert st.n == len(ls)
    assert int(st.alive[:st.hi].sum()) == st.n
    if st.n:
        assert st.lo == int(ls[0])
        assert st.alive[st.lo]
    else:
        assert st.lo == st.hi or not st.alive[st.lo:st.hi].any()
    # the FIFO prefix cache is exactly the first min(n, pref_cap) live
    # slots, and _prefix slices it without a live scan
    want = ls[:min(st.n, st.pref_cap)].tolist()
    assert st.pref == want
    if want:
        assert st._prefix(len(want)).tolist() == want
    # the next-live chain finds every live successor
    for s in range(st.lo, st.hi):
        if st.alive[s]:
            assert st._find(s) == s


@pytest.mark.parametrize("gone_rel, want", [
    ([0], [1, 2, 3, 4, 5, 6, 7]),            # head -> lo advances
    ([0, 1, 2], [3, 4, 5, 6, 7]),            # head block
    ([1], [0, 2, 3, 4, 5, 6, 7]),            # interior near head
    ([6], [0, 1, 2, 3, 4, 5, 7]),            # interior near tail
    ([7], [0, 1, 2, 3, 4, 5, 6]),            # tail
    ([1, 4, 6], [0, 2, 3, 5, 7]),            # batch
    ([0, 1, 2, 3, 4, 5, 6, 7], []),          # everything
])
def test_remove_preserves_order_and_prefix(gone_rel, want):
    st = _fill(8)
    ls = st.live_slots()
    st.remove([int(ls[g]) for g in gone_rel])
    assert _live_ids(st) == want
    _check_invariants(st)


def test_remove_fuzz_against_list_model():
    rng = np.random.default_rng(5)
    st = _fill(40)
    model = list(range(40))
    next_id = 40
    cluster = ClusterModel(capacity=16)
    row = JobSpec(job_id=0, arrival=0.0, epochs=1.0).speed_table(cluster)
    for _ in range(300):
        if model and rng.random() < 0.55:
            k = int(rng.integers(1, min(4, len(model)) + 1))
            rel = sorted(rng.choice(len(model), size=k, replace=False))
            ls = st.live_slots()
            st.remove([int(ls[int(r)]) for r in rel])
            for r in reversed(rel):
                del model[int(r)]
        else:
            st.add(JobSpec(job_id=next_id, arrival=0.0, epochs=50.0),
                   row, None)
            model.append(next_id)
            next_id += 1
        assert _live_ids(st) == model
        _check_invariants(st)


def test_prefix_refills_past_dead_runs():
    """Kill a long dead run just past the prefix tail: the refill must
    hop it through the compressed next-live chain, not scan."""
    st = _fill(40)
    ls = st.live_slots()
    # kill slots 16..31 (outside the 16-wide prefix), then a prefix slot
    st.remove([int(s) for s in ls[16:32]])
    _check_invariants(st)
    st.remove([int(st.live_slots()[3])])
    _check_invariants(st)
    # prefix refilled with slot 32 (first live past the dead run)
    assert st.pref[-1] == 32
