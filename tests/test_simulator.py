"""Scheduler simulation (§7): Table-3 qualitative structure."""
import numpy as np
import pytest

from repro.core.jobs import JobSpec, synthetic_workload
from repro.core.simulator import run_table3, simulate


@pytest.fixture(scope="module")
def table3():
    return run_table3(seed=0)


def test_all_jobs_complete():
    jobs = synthetic_workload(15, 600.0, 1)
    for strat in ("precompute", "exploratory", "fixed_8", "fixed_1"):
        res = simulate(jobs, 64, strat)
        assert len(res.completion_times) == 15, strat
        for j in jobs:
            assert res.completion_times[j.job_id] >= j.arrival


def test_none_contention_ties_paper_row(table3):
    """Paper Table 3 'None': precompute == eight (1.40 vs 1.40), exploratory
    slightly worse (1.47), one far worse (6.37)."""
    row = table3["none"]
    assert abs(row["precompute"] - row["fixed_8"]) < 0.15
    assert row["precompute"] <= row["exploratory"] <= row["precompute"] + 0.4
    assert row["fixed_1"] > 3 * row["precompute"]
    # quantitative: paper's 1.40 h at +-25%
    assert 1.0 < row["precompute"] < 1.8


def test_moderate_contention_dynamic_beats_fixed8(table3):
    """Paper: precompute 2.63 vs eight 6.20 under moderate contention."""
    row = table3["moderate"]
    assert row["precompute"] < row["fixed_8"]
    assert row["precompute"] < row["fixed_4"]
    assert row["precompute"] < row["fixed_1"]


def test_extreme_contention_precompute_beats_eight(table3):
    row = table3["extreme"]
    assert row["precompute"] < row["fixed_8"]
    assert row["precompute"] < row["exploratory"]  # explore cost hurts (§7)


def test_more_than_halving_claim(table3):
    """Abstract: 'more than halving of average job time on some workload
    patterns' — precompute vs the worst fixed strategy under contention."""
    for level in ("moderate", "extreme"):
        row = table3[level]
        worst_fixed = max(row[k] for k in row if k.startswith("fixed"))
        assert row["precompute"] * 2 < worst_fixed * 1.35, (level, row)


def test_restart_cost_applied():
    """A reallocation freezes the job ~10 s; total time with dynamic
    scheduling still beats static-1 despite restarts."""
    jobs = synthetic_workload(5, 2000.0, 2)
    dyn = simulate(jobs, 64, "precompute")
    one = simulate(jobs, 64, "fixed_1")
    assert dyn.avg_jct_hours < one.avg_jct_hours
