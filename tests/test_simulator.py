"""Scheduler simulation (§7): Table-3 qualitative structure."""
import numpy as np
import pytest

from repro.core.jobs import JobSpec, synthetic_workload
from repro.core.simulator import run_table3, simulate


@pytest.fixture(scope="module")
def table3():
    return run_table3(seed=0)


def test_all_jobs_complete():
    jobs = synthetic_workload(15, 600.0, 1)
    for strat in ("precompute", "exploratory", "fixed_8", "fixed_1"):
        res = simulate(jobs, 64, strat)
        assert len(res.completion_times) == 15, strat
        for j in jobs:
            assert res.completion_times[j.job_id] >= j.arrival


def test_none_contention_ties_paper_row(table3):
    """Paper Table 3 'None': precompute == eight (1.40 vs 1.40), exploratory
    slightly worse (1.47), one far worse (6.37)."""
    row = table3["none"]
    assert abs(row["precompute"] - row["fixed_8"]) < 0.15
    assert row["precompute"] <= row["exploratory"] <= row["precompute"] + 0.4
    assert row["fixed_1"] > 3 * row["precompute"]
    # quantitative: paper's 1.40 h at +-25%
    assert 1.0 < row["precompute"] < 1.8


def test_moderate_contention_dynamic_beats_fixed8(table3):
    """Paper: precompute 2.63 vs eight 6.20 under moderate contention."""
    row = table3["moderate"]
    assert row["precompute"] < row["fixed_8"]
    assert row["precompute"] < row["fixed_4"]
    assert row["precompute"] < row["fixed_1"]


def test_extreme_contention_precompute_beats_eight(table3):
    row = table3["extreme"]
    assert row["precompute"] < row["fixed_8"]
    assert row["precompute"] < row["exploratory"]  # explore cost hurts (§7)


def test_more_than_halving_claim(table3):
    """Abstract: 'more than halving of average job time on some workload
    patterns' — precompute vs the worst fixed strategy under contention."""
    for level in ("moderate", "extreme"):
        row = table3[level]
        worst_fixed = max(row[k] for k in row if k.startswith("fixed"))
        assert row["precompute"] * 2 < worst_fixed * 1.35, (level, row)


def test_table_engine_matches_reference_engine():
    """The table-driven engine must reproduce the reference event loop
    bit-for-bit: same completion times, same peak concurrency."""
    jobs = synthetic_workload(20, 400.0, 5)
    for strat in ("precompute", "exploratory", "fixed_8", "fixed_2"):
        fast = simulate(jobs, 64, strat, engine="table")
        ref = simulate(jobs, 64, strat, engine="reference")
        assert fast.completion_times == ref.completion_times, strat
        assert fast.peak_concurrency == ref.peak_concurrency, strat


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        simulate(synthetic_workload(2, 100.0, 0), 8, "precompute",
                 engine="bogus")


def test_engines_agree_with_heterogeneous_max_w():
    """Per-job max_w differing across the workload: both engines pass
    per-job caps to the doubling solvers (a max_w=2 job is never doubled
    past 2 even while a max_w=16 neighbour grows to 16) and must stay
    bit-identical to each other."""
    jobs = synthetic_workload(6, 300.0, 17)
    for j, mw in zip(jobs, (8, 2, 16, 4, 8, 2)):
        j.max_w = mw
    for strat in ("precompute", "exploratory"):
        fast = simulate(jobs, 24, strat, engine="table")
        ref = simulate(jobs, 24, strat, engine="reference")
        assert fast.completion_times == ref.completion_times, strat


def test_unsatisfiable_fixed_gang_rejected():
    """fixed_k with k > capacity would loop forever (every job gets the
    all-or-nothing 0 grant at each tick); the stall guard rejects it."""
    jobs = synthetic_workload(3, 100.0, 0)
    with pytest.raises(ValueError, match="can never run"):
        simulate(jobs, 4, "fixed_8")
    with pytest.raises(ValueError, match="capacity must be"):
        simulate(jobs, 0, "precompute")


def test_explore_gang_grant_clamped_to_capacity():
    """Two overlapping explore-phase jobs on a small cluster: the second
    explorer's gang reservation is clamped to what is left instead of the
    old all-or-nothing 8/0 grant that starved it outright."""
    from repro.core.simulator import _Active, _allocate, _allocate_table

    def make_active(jid, started):
        spec = JobSpec(job_id=jid, arrival=0.0, epochs=100.0)
        return _Active(spec=spec, remaining=100.0, explore_started=started)

    now = 1000.0
    started = now - (3 * 150.0 + 1.0)       # 4th segment: explore_w == 8
    active = [make_active(0, started), make_active(1, started)]
    for allocate in (_allocate, _allocate_table):
        alloc = allocate("exploratory", active, 10, now)
        assert alloc[0] == 8                # first explorer: full gang
        assert alloc[1] == 2                # second: clamped, not starved
        assert sum(alloc.values()) <= 10

    # with a dynamic job in the mix, the solver is handed cap >= 0 and the
    # total grant never exceeds the cluster
    active.append(_Active(spec=JobSpec(job_id=2, arrival=0.0, epochs=50.0),
                          remaining=50.0))
    for allocate in (_allocate, _allocate_table):
        alloc = allocate("exploratory", active, 10, now)
        assert sum(alloc.values()) <= 10
        assert all(w >= 0 for w in alloc.values())


def test_exploratory_completes_on_small_cluster():
    """Overlapping explorers on an 8-GPU cluster must all finish (the
    pre-clamp code starved late arrivals of even their explore workers)."""
    jobs = synthetic_workload(6, 200.0, 7)
    res = simulate(jobs, 8, "exploratory")
    assert len(res.completion_times) == 6
    assert res.completion_times == simulate(
        jobs, 8, "exploratory", engine="reference").completion_times


def test_restart_cost_applied():
    """A reallocation freezes the job ~10 s; total time with dynamic
    scheduling still beats static-1 despite restarts."""
    jobs = synthetic_workload(5, 2000.0, 2)
    dyn = simulate(jobs, 64, "precompute")
    one = simulate(jobs, 64, "fixed_1")
    assert dyn.avg_jct_hours < one.avg_jct_hours
