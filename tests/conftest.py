import os
import sys

# src layout import path (so plain `pytest tests/` works too)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests dir itself, so `from _hypothesis_compat import ...` resolves even
# when pytest is invoked from outside the repo root
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; multi-device tests spawn subprocesses.
