"""Dry-run machinery test on an 8-device tiny mesh (subprocess — the main
pytest process must keep 1 device).  Full-size 256/512-device runs are the
EXPERIMENTS.md sweep; this validates the lowering path per family x kind."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Subprocess dry-runs take minutes: keep them out of the fast CI lane.
pytestmark = pytest.mark.slow

# Seed failures tracked in ISSUE 2: the container's jax predates
# jax.sharding.AxisType, so every dryrun subprocess dies at import.  xfail
# (non-strict) keeps CI green without hiding a fix or a new regression.
_SEED_XFAIL = pytest.mark.xfail(
    strict=False,
    reason="seed failure (ISSUE 2): container jax predates "
           "jax.sharding.AxisType; dryrun subprocess fails at import")

CASES = [
    ("qwen2.5-3b", "train_4k", "single"),
    ("qwen3-moe-30b-a3b", "prefill_32k", "single"),
    ("mamba2-780m", "decode_32k", "multi"),
    ("jamba-v0.1-52b", "train_4k", "multi"),
    ("whisper-base", "decode_32k", "single"),
]


@_SEED_XFAIL
@pytest.mark.parametrize("arch,shape,mesh", CASES)
def test_dryrun_tiny(arch, shape, mesh):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8", PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--tiny", "--skip-costs"],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK " in r.stdout


@_SEED_XFAIL
def test_dryrun_records_roofline_terms(tmp_path):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8", PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gemma-2b",
         "--shape", "train_4k", "--mesh", "single", "--tiny",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    import json
    files = list(tmp_path.iterdir())
    assert len(files) == 1
    rec = json.loads(files[0].read_text())
    roof = rec["roofline"]
    for key in ("compute_s", "memory_s", "collective_s", "dominant"):
        assert key in roof
    assert roof["compute_s"] > 0
    assert rec["memory"]["peak_bytes_per_device"] > 0
    assert rec["useful_flops_ratio"] is not None
    # scan-corrected flops must be ~L x the body-once raw number
    assert (roof["flops_per_device"]
            > 4 * rec["raw_costs_scan_body_once"]["flops"])
