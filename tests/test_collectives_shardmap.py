"""Executable ring / halving-doubling all-reduce under shard_map, validated
against lax.psum on 8 host devices (subprocess so the main test process
keeps 1 device)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax import shard_map
from repro.collectives.xla import (ring_allreduce,
                                   halving_doubling_allreduce, exchange_tree)

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 45)).astype(np.float32))

for name, fn in [("ring", ring_allreduce),
                 ("dh", halving_doubling_allreduce)]:
    @partial(shard_map, mesh=mesh, in_specs=P("data", None),
             out_specs=P("data", None), check_vma=False)
    def run(xs):
        return fn(xs[0], "data")[None]
    out = np.asarray(run(x))
    want = np.asarray(x.sum(0))
    assert np.allclose(out, want[None], atol=1e-4), name
    # also against psum
    @partial(shard_map, mesh=mesh, in_specs=P("data", None),
             out_specs=P("data", None), check_vma=False)
    def run_psum(xs):
        return jax.lax.psum(xs[0], "data")[None]
    assert np.allclose(out, np.asarray(run_psum(x)), atol=1e-4), name

# fusion-buffer tree exchange
tree = {"a": x[:, :10], "b": x[:, 10:].reshape(8, 35)}
@partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
         check_vma=False)
def run_tree(t):
    local = jax.tree.map(lambda v: v[0], t)
    out = exchange_tree(local, "data", "doubling_halving")
    return jax.tree.map(lambda v: v[None], out)
out = run_tree(tree)
assert np.allclose(np.asarray(out["a"]), np.asarray(tree["a"].sum(0))[None],
                   atol=1e-4)
assert np.allclose(np.asarray(out["b"]), np.asarray(tree["b"].sum(0))[None],
                   atol=1e-4)

# end-to-end: explicit-exchange DP training step == psum step
from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.engine.steps import make_train_step, init_train_state
from repro.optim.optimizers import sgd

cfg = get_smoke_config("gemma-2b")
model = build_model(cfg)
opt = sgd()
state = init_train_state(model, opt)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

outs = {}
for mode in ("psum", "ring"):
    step = make_train_step(model, opt, grad_exchange=mode)
    jitted = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P(), {"tokens": P("data"), "labels": P("data")}, P()),
        out_specs=(P(), P()), check_vma=False))
    new_state, loss = jitted(state, batch, jnp.float32(0.1))
    outs[mode] = (new_state, float(loss))
leaves_a = jax.tree.leaves(outs["psum"][0])
leaves_b = jax.tree.leaves(outs["ring"][0])
for a, b in zip(leaves_a, leaves_b):
    assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                       atol=2e-3), "ring-exchange step != psum step"
print("SHARDMAP_OK")
"""


@pytest.mark.xfail(
    strict=False,
    reason="seed failure (ISSUE 2): container jax predates "
           "jax.sharding.AxisType / jax.shard_map, so the 8-device "
           "subprocess dies at import; passes on jax >= 0.4.35")
def test_shardmap_allreduce_8dev():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDMAP_OK" in r.stdout
