"""MoE dispatch tests: exactness vs dense-all-experts at ample capacity,
drop behaviour at tight capacity, aux-loss properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import moe as moe_lib
from repro.models.layers import NO_SHARD
from repro.models.spec import init_params


def setup(cf=8.0, E=4, K=2, seed=0):
    cfg = dataclasses.replace(get_smoke_config("dbrx-132b"),
                              capacity_factor=cf, n_experts=E, top_k=K)
    specs = moe_lib.moe_specs(cfg, 1)
    p = init_params(jax.random.PRNGKey(seed), specs)
    p1 = {k: v[0] for k, v in p.items()}
    return cfg, p1


def dense_ref(cfg, p1, x):
    logits = jnp.einsum("bsd,de->bse", x, p1["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, eid = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    B, S, _ = x.shape
    g_full = jnp.zeros_like(probs).at[
        jnp.arange(B)[:, None, None], jnp.arange(S)[None, :, None], eid
    ].set(gate)
    h = (jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p1["wi_gate"]))
         * jnp.einsum("bsd,edf->bsef", x, p1["wi_up"]))
    return jnp.einsum("bsef,efd,bse->bsd", h, p1["wo"], g_full)


@pytest.mark.parametrize("E,K,B,S", [(4, 2, 3, 16), (8, 1, 2, 8),
                                     (4, 4, 1, 32), (2, 2, 2, 5)])
def test_dispatch_exact_at_ample_capacity(E, K, B, S):
    cfg, p1 = setup(cf=8.0, E=E, K=K)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model),
                          jnp.float32)
    out, aux = moe_lib.moe_ffn(cfg, p1, x, NO_SHARD)
    want = dense_ref(cfg, p1, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) >= 1.0 - 1e-6  # Switch aux lower bound is 1 (balanced)


def test_tight_capacity_drops_but_stays_finite():
    cfg, p1 = setup(cf=0.25)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    out, aux = moe_lib.moe_ffn(cfg, p1, x, NO_SHARD)
    want = dense_ref(cfg, p1, x)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))
    # some tokens dropped => outputs differ from the no-drop reference
    assert float(jnp.max(jnp.abs(out - want))) > 1e-4


def test_group_capacity():
    cfg, _ = setup(cf=1.25, E=4, K=2)
    C = moe_lib.group_capacity(64, cfg)
    assert C >= 64 * 2 * 1.25 / 4
    assert C % 8 == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_combine_weights_bounded(seed):
    """Output norm can't exceed the max expert output norm (convex gates)."""
    cfg, p1 = setup(cf=8.0, seed=seed % 3)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, cfg.d_model))
    out, _ = moe_lib.moe_ffn(cfg, p1, x, NO_SHARD)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_expert_param_accounting():
    from repro.configs import get_config
    cfg = get_config("qwen3-moe-30b-a3b")
    from repro.models.registry import build_model
    model = build_model(cfg)
    sub = model.expert_param_specs()
    assert sub  # expert weights found
    assert all("experts" in t.axes for t in sub.values())
