"""SSD correctness: the chunked (state-space duality) form must match the
naive O(S*N) sequential recurrence exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import mamba2 as m2
from repro.models.layers import NO_SHARD
from repro.models.spec import init_params


def naive_ssm(xin, Bm, Cm, dt, a):
    """Reference recurrence: h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t;
    y_t = C_t h_t.  All f32.  Shapes: xin [B,S,H,P], Bm/Cm [B,S,N],
    dt [B,S,H], a [H]."""
    B, S, H, P = xin.shape
    N = Bm.shape[-1]
    h = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        decay = np.exp(dt[:, t] * a)[:, :, None, None]       # [B,H,1,1]
        dBx = np.einsum("bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xin[:, t])
        h = h * decay + dBx
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], h)
    return ys


@pytest.mark.parametrize("S,chunk", [(32, 8), (24, 16), (16, 16), (7, 4)])
def test_chunked_ssd_matches_naive(S, chunk):
    rng = np.random.default_rng(0)
    B, H, P, N = 2, 3, 4, 5
    xin = rng.normal(size=(B, S, H, P)).astype(np.float32)
    Bm = rng.normal(size=(B, S, N)).astype(np.float32)
    Cm = rng.normal(size=(B, S, N)).astype(np.float32)
    dt = np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.5
    a = -np.abs(rng.normal(size=(H,))).astype(np.float32)

    want = naive_ssm(xin, Bm, Cm, dt, a)

    # drive the chunked path directly (mirrors mamba_mixer's inner loop)
    dA = dt * a
    pad = (-S) % chunk
    def padd(t):
        return np.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
    xin_p, Bm_p, Cm_p, dt_p, dA_p = map(padd, (xin, Bm, Cm, dt, dA))
    nc = (S + pad) // chunk

    def chunkify(t):
        return jnp.asarray(t.reshape((B, nc, chunk) + t.shape[2:])
                           .swapaxes(0, 1))

    import repro.models.layers as L

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    NEG_INF = -1e30

    def body(h, xs):
        xc, Bc, Cc, dtc, dAc = xs
        cs = jnp.cumsum(dAc, axis=1)
        CB = jnp.einsum("bin,bjn->bij", Cc, Bc)
        diff = cs[:, :, None, :] - cs[:, None, :, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.exp(jnp.where(mask[None, :, :, None], diff, NEG_INF))
        M = CB[:, :, :, None] * decay * dtc[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xc)
        y_inter = jnp.einsum("bin,bhpn->bihp", Cc, h)
        y_inter = y_inter * jnp.exp(cs)[:, :, :, None]
        w = jnp.exp(cs[:, -1:, :] - cs) * dtc
        dh = jnp.einsum("bjh,bjn,bjhp->bhpn", w, Bc, xc)
        h = h * jnp.exp(cs[:, -1])[:, :, None, None] + dh
        return h, y_intra + y_inter

    _, y = jax.lax.scan(body, h0, tuple(map(chunkify,
                                            (xin_p, Bm_p, Cm_p, dt_p, dA_p))))
    got = np.asarray(y.swapaxes(0, 1).reshape(B, S + pad, H, P)[:, :S])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_mixer_decode_matches_mixer_forward_f32():
    """mamba_mixer (chunked, full seq) vs mamba_decode (recurrent, step by
    step) through the full layer incl. conv/gating, in f32."""
    cfg = get_smoke_config("mamba2-780m")
    specs = m2.mamba_specs(cfg, 1)
    from repro.models.spec import cast
    p = init_params(jax.random.PRNGKey(0), cast(specs, jnp.float32))
    p1 = {k: (v[0] if not isinstance(v, dict)
              else {kk: vv[0] for kk, vv in v.items()})
          for k, v in p.items()}
    rng = np.random.default_rng(0)
    B, S = 2, 20
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.3, jnp.float32)

    full = m2.mamba_mixer(cfg, p1, x, NO_SHARD)

    H, P, N, K = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state,
                  cfg.ssm_conv)
    state = {"conv_x": jnp.zeros((B, K - 1, H, P)),
             "conv_B": jnp.zeros((B, K - 1, N)),
             "conv_C": jnp.zeros((B, K - 1, N)),
             "ssm": jnp.zeros((B, H, P, N))}
    outs = []
    for t in range(S):
        y, state = m2.mamba_decode(cfg, p1, x[:, t:t + 1], state, NO_SHARD)
        outs.append(y[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-3, atol=1e-3)
