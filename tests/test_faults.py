"""Fault injection + elastic recovery (PR 10): fault-model registry and
schedule determinism, the zero-fault bit-identity gate (60-job goldens +
1000-job sha256 with the fault machinery threaded through), cross-engine
parity under churn, ClusterState fault-lifecycle invariants (deterministic
and hypothesis), checkpoint-age-dependent lost work, the failure-aware
policy's goodput edge, and the hardened CheckpointStore (atomic sidecars,
corrupt-snapshot fallback) wired through ElasticTrainer."""
import dataclasses
import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from test_placement import (FLAT_PLACED, FRAG, GOLDEN_1000JOB_SHA256,
                            GOLDEN_60JOB_JCT_HOURS, _trace_sha256)

from repro.checkpoint.store import CheckpointStore
from repro.collectives.cost import ClusterModel, NodeSpec
from repro.core import faults as F
from repro.core import placement as P
from repro.core import scheduler as S
from repro.core import telemetry as tele
from repro.core.elastic import ElasticTrainer
from repro.core.jobs import WORKLOAD_PATTERNS, make_workload, \
    synthetic_workload
from repro.core.simulator import simulate
from repro.optim.optimizers import sgd


# --------------------------------------------------------------------------
# Registry + validation
# --------------------------------------------------------------------------

def test_fault_registry_round_trip():
    assert F.registered_fault_models() == (
        "churn", "drain", "kill", "none", "rack", "stragglers")
    assert isinstance(F.get_fault_model("none"), F.NoFaults)
    assert F.get_fault_model("kill_1800").t == 1800.0
    assert F.get_fault_model("churn_3").n == 3
    assert F.get_fault_model("drain_900").t == 900.0
    assert F.get_fault_model("stragglers_2").k == 2
    assert F.get_fault_model("rack_7000").t == 7000.0
    # instances pass through
    model = F.StochasticChurn(5)
    assert F.get_fault_model(model) is model
    for bad, match in [("bogus", "unknown fault model"),
                       ("churn", "needs an integer"),
                       ("churn_x", "must be an integer"),
                       ("none_3", "takes no parameter"),
                       ("kill_0", "must be >= 1"),
                       (7, "must be a non-empty string")]:
        with pytest.raises(ValueError, match=match):
            F.get_fault_model(bad)


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        F.FaultEvent(0.0, "explode", 0)
    with pytest.raises(ValueError, match="degrade factor"):
        F.FaultEvent(0.0, "degrade", 0, factor=0.0)
    with pytest.raises(ValueError, match="degrade factor"):
        F.FaultEvent(0.0, "degrade", 0, factor=1.5)
    assert F.FaultEvent(0.0, "degrade", 0, factor=0.5).factor == 0.5


def test_checkpoint_policy_lost_progress():
    cp = F.CheckpointPolicy(interval=300.0)
    assert cp.lost_progress(0.0) == 0.0
    assert cp.lost_progress(-5.0) == 0.0
    assert cp.lost_progress(250.0) == 250.0   # no checkpoint yet
    assert cp.lost_progress(300.0) == 0.0     # exactly at a checkpoint
    assert cp.lost_progress(650.0) == 50.0
    with pytest.raises(ValueError, match="interval must be > 0"):
        F.CheckpointPolicy(interval=0.0)


def test_cluster_model_fault_validation():
    with pytest.raises(ValueError, match="faults without placement"):
        ClusterModel(capacity=64, faults="churn_3")
    with pytest.raises(ValueError, match="checkpoint_interval without"):
        ClusterModel(capacity=64, checkpoint_interval=100.0)
    with pytest.raises(ValueError, match="checkpoint_interval must be > 0"):
        dataclasses.replace(FRAG, faults="churn_3",
                            checkpoint_interval=-1.0)
    # model/cluster combinations that cannot work are rejected up front
    with pytest.raises(ValueError, match="single-node"):
        ClusterModel(capacity=8, placement="packed", faults="churn_3")
    with pytest.raises(ValueError, match="at.*least one at full speed"):
        dataclasses.replace(FRAG, faults="stragglers_4")
    with pytest.raises(ValueError, match="survivors"):
        ClusterModel(capacity=8, placement="packed", faults="rack_100")
    # a valid combination constructs fine
    assert dataclasses.replace(FRAG, faults="churn_3").faults == "churn_3"


# --------------------------------------------------------------------------
# Schedule determinism
# --------------------------------------------------------------------------

ALL_SPECS = ("none", "kill_1800", "churn_6", "drain_1800", "stragglers_2",
             "rack_7000")


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_schedule_is_pure_and_sorted(spec):
    """Same (cluster, seed, horizon) -> bit-identical schedule on every
    call — both engines build the tape independently and must agree."""
    model = F.get_fault_model(spec)
    a = model.schedule(FRAG, 7, 20_000.0)
    b = model.schedule(FRAG, 7, 20_000.0)
    assert a == b
    assert list(e.t for e in a) == sorted(e.t for e in a)
    for e in a:
        assert 0 <= e.node < len(FRAG.node_specs())


def test_churn_schedule_varies_with_seed():
    model = F.get_fault_model("churn_6")
    assert model.schedule(FRAG, 7, 20_000.0) != \
        model.schedule(FRAG, 8, 20_000.0)


# --------------------------------------------------------------------------
# Zero-fault bit-identity: the fault machinery threaded through with an
# empty schedule must not move a single completion time.
# --------------------------------------------------------------------------

FLAT_NOFAULT = dataclasses.replace(FLAT_PLACED, faults="none")


@pytest.mark.parametrize("strat", sorted(GOLDEN_60JOB_JCT_HOURS))
def test_zero_fault_preserves_60job_golden_values(strat):
    jobs = synthetic_workload(60, 500.0, 0)
    res = simulate(jobs, strategy=strat, cluster=FLAT_NOFAULT)
    assert res.avg_jct_hours == GOLDEN_60JOB_JCT_HOURS[strat], strat
    assert res.evictions == 0


@pytest.mark.parametrize("pattern", sorted(WORKLOAD_PATTERNS))
def test_zero_fault_1000job_sha256(pattern):
    jobs = make_workload(pattern, 1000, 250.0, 0)
    res = simulate(jobs, strategy="precompute", cluster=FLAT_NOFAULT)
    assert _trace_sha256(res) == GOLDEN_1000JOB_SHA256[pattern], pattern


# --------------------------------------------------------------------------
# Engine parity + trajectory determinism under faults
# --------------------------------------------------------------------------

CHURN = dataclasses.replace(FRAG, faults="churn_3", fault_seed=5,
                            checkpoint_interval=200.0)


def test_churn_engine_parity_every_policy():
    """Identical seeds give identical trajectories on both engines, for
    every registry entry (future policies are gated automatically)."""
    jobs = make_workload("mixed_maxw", 20, 500.0, 7)
    for strat in S.registered_policies().values():
        fast = simulate(jobs, strategy=strat, cluster=CHURN)
        again = simulate(jobs, strategy=strat, cluster=CHURN)
        assert fast.completion_times == again.completion_times, strat
        ref = simulate(jobs, strategy=strat, cluster=CHURN,
                       engine="reference")
        assert fast.completion_times == ref.completion_times, strat
        assert fast.evictions == ref.evictions, strat
        assert fast.migrations == ref.migrations, strat
        assert fast.rejected == ref.rejected, strat


@pytest.mark.parametrize("spec", ["kill_2000", "drain_2000", "rack_7000",
                                  "stragglers_1"])
def test_fault_kind_engine_parity(spec):
    cluster = dataclasses.replace(FRAG, faults=spec, fault_seed=3)
    jobs = make_workload("mixed_maxw", 16, 400.0, 2)
    for strat in ("srtf", "pack_srtf", "recovery_aware"):
        fast = simulate(jobs, strategy=strat, cluster=cluster)
        ref = simulate(jobs, strategy=strat, cluster=cluster,
                       engine="reference")
        assert fast.completion_times == ref.completion_times, (spec, strat)
        assert fast.evictions == ref.evictions, (spec, strat)


def test_scheduled_kill_evicts_and_recovers():
    """A kill while gangs are running evicts them (telemetry agrees on
    the count), yet every job still completes — evicted gangs re-enter
    through admission and finish after the node returns."""
    cluster = dataclasses.replace(FRAG, faults="kill_2000", fault_seed=0,
                                  checkpoint_interval=200.0)
    jobs = make_workload("mixed_maxw", 16, 400.0, 2)
    res = simulate(jobs, strategy="srtf", cluster=cluster,
                   telemetry=tele.Telemetry())
    assert res.evictions > 0
    assert len(res.completion_times) == 16
    roll = res.telemetry.rollup()
    assert roll["n_evictions"] == res.evictions
    assert roll["n_faults"] == 2          # the kill and the recover
    assert 0.0 <= roll["goodput"] <= 1.0
    # lost work costs goodput: the same trace without faults scores 1.0
    clean = simulate(jobs, strategy="srtf",
                     cluster=dataclasses.replace(cluster, faults="none"),
                     telemetry=tele.Telemetry())
    assert res.telemetry.goodput < clean.telemetry.goodput


def test_eviction_rolls_back_to_last_checkpoint():
    """Tighter checkpoints lose less work: the same kill under a smaller
    checkpoint_interval never scores lower goodput."""
    jobs = make_workload("mixed_maxw", 16, 400.0, 2)
    goodput = {}
    for interval in (50.0, 1000.0):
        cluster = dataclasses.replace(FRAG, faults="kill_2000",
                                      fault_seed=0,
                                      checkpoint_interval=interval)
        res = simulate(jobs, strategy="srtf", cluster=cluster,
                       telemetry=tele.Telemetry())
        goodput[interval] = res.telemetry.goodput
    assert goodput[50.0] >= goodput[1000.0]


# --------------------------------------------------------------------------
# ClusterState fault lifecycle: invariants under kill/drain/recover
# --------------------------------------------------------------------------

def test_fail_node_evicts_and_zeroes_capacity():
    state = P.ClusterState((NodeSpec(8), NodeSpec(8)))
    state.assign(P.Placement(1, ((0, 4), (1, 4))))   # spanning gang
    state.assign(P.Placement(2, ((1, 2),)))
    victims = state.fail_node(0)
    assert victims == [1]                 # only the gang touching node 0
    assert state.free[0] == 0             # dead node holds nothing
    assert state.free[1] == 6             # node-1 slots of the victim
    assert 2 in state.placements          # survivor untouched
    state.check_invariants(16)
    state.recover_node(0)
    assert state.free[0] == 8
    state.check_invariants(16)


def test_release_on_failed_node_does_not_resurrect_gpus():
    """Regression (satellite 2): releasing a gang that held slots on a
    failed node must not credit the dead node's GPUs back."""
    state = P.ClusterState((NodeSpec(8), NodeSpec(8)))
    state.assign(P.Placement(1, ((0, 4), (1, 4))))
    state.ok[0] = False                   # node dies with the gang live
    state.free[0] = 0
    state._refresh_mask()
    state.release(1)
    assert state.free[0] == 0             # dead node stays empty
    assert state.free[1] == 8             # healthy slots come back
    state.check_invariants(16)
    # releasing an already-released job is a no-op
    assert state.release(1) is None


def test_engine_tolerates_redundant_incidents():
    """Stochastic churn can draw the same node twice with overlapping
    outages: a second kill (or drain) of a down node is a no-op."""
    eng = P.PlacementEngine(
        ClusterModel(capacity=16, gpus_per_node=8,
                     inter_node_beta=1.0 / 1.25e8, placement="packed"))
    assert eng.fail(0) == []
    assert eng.fail(0) == []              # already dead: no-op
    eng.drain(1)
    eng.drain(1)                          # already draining: no-op
    eng.recover(0)
    eng.recover(1)
    eng.state.check_invariants(16)


def test_drain_keeps_running_gangs_but_blocks_new_ones():
    state = P.ClusterState((NodeSpec(8), NodeSpec(8)))
    state.assign(P.Placement(1, ((0, 8),)))
    state.drain_node(1)
    assert 1 in state.placements          # running gang stays
    assert state.free[1] == 8             # GPUs still physically free...
    assert int(state.avail.sum()) == 0    # ...but closed to placement
    strat = P.get_placement("packed")
    state.recover_node(1)
    assert strat.place(state, 4) == ((1, 4),)
    state.check_invariants(16)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 3),
                          st.integers(1, 12)),
                min_size=1, max_size=50))
def test_fault_lifecycle_invariants_property(ops):
    """Hypothesis: arbitrary interleavings of place / release / kill /
    recover / degrade never oversubscribe a node, never leave GPUs on a
    dead node, and conserve grants against surviving capacity."""
    nodes = (NodeSpec(8), NodeSpec(8), NodeSpec(4), NodeSpec(4))
    state = P.ClusterState(nodes)
    strat = P.get_placement("best_fit")
    live, jid = [], 0
    for action, node, w in ops:
        node = node % len(nodes)
        if action == 0 and w <= int(state.avail.sum()):
            state.assign(P.Placement(jid, strat.place(state, w)))
            live.append(jid)
            jid += 1
        elif action == 1 and live:
            state.release(live.pop(0))
        elif action == 2 and state.ok[node]:
            dead = state.fail_node(node)
            live = [j for j in live if j not in dead]
        elif action == 3:
            state.recover_node(node)
        elif action == 4:
            state.set_speed_mult(node, 0.5)
        state.check_invariants(24)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_simulation_survives_churn_property(seed):
    """Hypothesis: across whole churned traces every job either
    completes or is explicitly rejected — nothing is lost in a crash."""
    cluster = dataclasses.replace(FRAG, faults="churn_2", fault_seed=seed,
                                  checkpoint_interval=200.0)
    jobs = make_workload("mixed_maxw", 12, 300.0, seed % 1000)
    res = simulate(jobs, strategy="precompute", cluster=cluster)
    assert len(res.completion_times) + len(res.rejected) == 12


# --------------------------------------------------------------------------
# The failure-aware policy: goodput is the score that shows the win
# --------------------------------------------------------------------------

def test_recovery_aware_beats_blind_srtf_on_goodput():
    """The robustness acceptance row: under stochastic churn the
    failure-aware policy (gangs clamped to healthy full-speed nodes)
    holds more goodput than blind srtf, whose node-spanning rings die
    wholesale with every node."""
    cluster = dataclasses.replace(FRAG, capacity=64, gpus_per_node=8,
                                  faults="churn_6", fault_seed=7,
                                  checkpoint_interval=200.0)
    jobs = make_workload("mixed_maxw", 114, 500.0, 0)
    score = {}
    for strat in ("srtf", "recovery_aware"):
        res = simulate(jobs, strategy=strat, cluster=cluster,
                       telemetry=tele.Telemetry())
        score[strat] = res.telemetry.goodput
    assert score["recovery_aware"] > score["srtf"], score


def test_recovery_aware_is_plain_srtf_on_flat_cluster():
    """Without a placement engine there is nothing to route around: the
    failure-aware policy must rank exactly like srtf."""
    jobs = synthetic_workload(40, 500.0, 3)
    a = simulate(jobs, 64, "recovery_aware")
    b = simulate(jobs, 64, "recovery_aware", engine="reference")
    assert a.completion_times == b.completion_times


# --------------------------------------------------------------------------
# CheckpointStore hardening (satellite 1) + lost-work integration
# --------------------------------------------------------------------------

def _corrupt(path: str, keep: int = 40) -> None:
    """Truncate a file to ``keep`` bytes — a torn write."""
    with open(path, "rb") as f:
        head = f.read(keep)
    with open(path, "wb") as f:
        f.write(head)


def test_save_leaves_no_tmp_files():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(3, {"x": jnp.ones(4)}, meta={"w": 2})
        names = sorted(os.listdir(d))
        assert names == ["ckpt_0000000003.json", "ckpt_0000000003.npz"]


def test_restore_falls_back_past_corrupt_snapshot():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        template = {"x": jnp.zeros(4)}
        store.save(5, {"x": jnp.full(4, 5.0)})
        store.save(9, {"x": jnp.full(4, 9.0)})
        _corrupt(os.path.join(d, "ckpt_0000000009.npz"))
        assert store.latest_step() == 5   # torn snapshot is not a target
        state, _, _ = store.restore(template)
        np.testing.assert_array_equal(np.asarray(state["x"]),
                                      np.full(4, 5.0))
        # an explicit step is trusted: corruption there raises
        with pytest.raises(Exception):
            store.restore(template, step=9)


def test_restore_with_all_snapshots_corrupt_raises():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(1, {"x": jnp.ones(2)})
        _corrupt(os.path.join(d, "ckpt_0000000001.npz"))
        assert store.latest_step() is None
        with pytest.raises(FileNotFoundError, match="no readable"):
            store.restore({"x": jnp.zeros(2)})


def test_corrupt_manifest_degrades_to_empty_meta():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(2, {"x": jnp.ones(2)}, meta={"w": 8})
        mpath = os.path.join(d, "ckpt_0000000002.json")
        with open(mpath, "w") as f:
            f.write("{not json")
        state, meta, _ = store.restore({"x": jnp.zeros(2)})
        assert meta == {}                 # arrays win; sidecar is advisory
        os.remove(mpath)                  # missing sidecar: same story
        _, meta, _ = store.restore({"x": jnp.zeros(2)})
        assert meta == {}


def test_steps_skips_foreign_files():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(4, {"x": jnp.ones(2)})
        with open(os.path.join(d, "ckpt_stray.npz"), "w") as f:
            f.write("not a checkpoint")
        assert store.steps() == [4]
        assert store.latest_step() == 4


class _TinyModel:
    """Linear least squares — enough structure for save/restore."""

    def init(self, key):
        return {"w": jnp.zeros((3,))}

    def loss(self, params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)


class _TinyData:
    size = 64

    def __init__(self):
        rng = np.random.default_rng(0)
        self._x = rng.normal(size=(64, 3))
        self._w = np.array([1.0, -2.0, 0.5])

    def batch(self, step, n):
        idx = (np.arange(n) + step * n) % self.size
        return {"x": self._x[idx], "y": self._x[idx] @ self._w}


def test_trainer_crash_rolls_back_exactly_checkpoint_policy_loss():
    """End-to-end lost-work model: train 12 steps (checkpoint), train 7
    more whose checkpoint is torn mid-write — restore lands back on step
    12, and the 7 lost steps equal CheckpointPolicy(interval=12)'s
    prediction for a crash at progress 19."""
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        tr = ElasticTrainer(_TinyModel(), sgd(), _TinyData(), store,
                            base_lr_1w=0.05, m_per_worker=8,
                            dataset_size=64)
        tr.train_segment(w=1, n_steps=12, resume=False, log_every=4)
        tr.train_segment(w=1, n_steps=7, resume=True, log_every=4)
        assert store.steps() == [12, 19]
        _corrupt(os.path.join(d, "ckpt_0000000019.npz"))   # the crash
        state, _, _ = store.restore(tr.fresh_state())
        resumed_at = int(state["step"])
        assert resumed_at == 12
        lost = 19 - resumed_at
        assert lost == F.CheckpointPolicy(interval=12.0).lost_progress(19.0)


def test_fault_events_reach_the_event_stream():
    """The structured event stream carries the new fault/evict/recover
    kinds with node + lost-work attribution."""
    cluster = dataclasses.replace(FRAG, faults="kill_2000", fault_seed=0,
                                  checkpoint_interval=200.0)
    jobs = make_workload("mixed_maxw", 16, 400.0, 2)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "events.jsonl")
        res = simulate(jobs, strategy="srtf", cluster=cluster,
                       telemetry=tele.Telemetry(sink=tele.JSONLSink(path)))
        with open(path) as f:
            events = [json.loads(line) for line in f]
    kinds = {e["kind"] for e in events}
    assert {"fault", "evict", "recover"} <= kinds
    evicts = [e for e in events if e["kind"] == "evict"]
    assert len(evicts) == res.evictions
    for e in evicts:
        assert e["node"] >= 0 and e["lost"] >= 0.0
