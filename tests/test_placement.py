"""Node-level placement engine (PR 4): strategy/admission registries,
ClusterState invariants (no oversubscription, GPU conservation — both as
deterministic checks and hypothesis properties), the flat-cluster
bit-identical no-op gate (60-job golden values + 1000-job sha256 across
all five workload patterns), engine parity on placement clusters,
migration/defrag, admission control, heterogeneous per-node hardware,
and the placement-aware-beats-blind Table-3 acceptance scenario."""
import dataclasses
import hashlib
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.collectives.cost import (ClusterModel, INFINIBAND_100G, NodeSpec)
from repro.core import placement as P
from repro.core import scheduler as S
from repro.core.jobs import (JobSpec, WORKLOAD_PATTERNS, make_workload,
                             synthetic_workload)
from repro.core.simulator import simulate


# --------------------------------------------------------------------------
# Registries
# --------------------------------------------------------------------------

def test_placement_registry_round_trip():
    assert P.registered_placements() == ("best_fit", "packed", "spread")
    for name in P.registered_placements():
        strat = P.get_placement(name)
        assert isinstance(strat, P.PlacementStrategy)
        assert strat.name == name
    with pytest.raises(ValueError, match="unknown placement strategy"):
        P.get_placement("bogus")
    with pytest.raises(ValueError, match="already registered"):
        P.register_placement(P.PackedPlacement)


def test_admission_registry_round_trip():
    assert P.get_admission("admit_all").spec == "admit_all"
    assert P.get_admission("queue_cap_12").n == 12
    assert P.get_admission("free_gpus_8").k == 8
    for bad, match in [("bogus", "unknown admission rule"),
                       ("queue_cap", "needs an integer"),
                       ("queue_cap_x", "must be an integer"),
                       ("free_gpus_0", "must be >= 1"),
                       ("admit_all_3", "takes no parameter")]:
        with pytest.raises(ValueError, match=match):
            P.get_admission(bad)


def test_cluster_model_placement_validation():
    with pytest.raises(ValueError, match="unknown placement strategy"):
        ClusterModel(placement="bogus")
    with pytest.raises(ValueError, match="unknown admission rule"):
        ClusterModel(placement="packed", admission="bogus")
    with pytest.raises(ValueError, match="admission rule without placement"):
        ClusterModel(admission="queue_cap_4")
    with pytest.raises(ValueError, match="defrag without placement"):
        ClusterModel(defrag=True)
    with pytest.raises(ValueError, match="nodes without placement"):
        ClusterModel(capacity=16, nodes=(NodeSpec(16),))
    with pytest.raises(ValueError, match="not both"):
        ClusterModel(capacity=16, nodes=(NodeSpec(16),), gpus_per_node=8,
                     inter_node_beta=1e-9, placement="packed")
    with pytest.raises(ValueError, match="sum to"):
        ClusterModel(capacity=64, nodes=(NodeSpec(8),), placement="packed")
    with pytest.raises(ValueError, match="needs inter_node_beta"):
        ClusterModel(capacity=16, nodes=(NodeSpec(8), NodeSpec(8)),
                     placement="packed")
    with pytest.raises(ValueError, match="can never admit"):
        ClusterModel(capacity=8, placement="packed",
                     admission="free_gpus_64")
    with pytest.raises(ValueError, match="gpus must be >= 1"):
        NodeSpec(0)
    # a flat placement cluster is legal and not "flat" (engine runs)
    assert not ClusterModel(capacity=8, placement="packed").is_flat
    assert ClusterModel(capacity=8).is_flat


def test_node_specs_layouts():
    assert ClusterModel(capacity=8).node_specs() == (NodeSpec(8),)
    uniform = ClusterModel(capacity=20, gpus_per_node=8,
                           inter_node_beta=1e-9).node_specs()
    assert [n.gpus for n in uniform] == [8, 8, 4]   # last node partial
    explicit = (NodeSpec(8), NodeSpec(4))
    assert ClusterModel(capacity=12, nodes=explicit, inter_node_beta=1e-9,
                        placement="packed").node_specs() == explicit


# --------------------------------------------------------------------------
# Strategies: concrete assignments
# --------------------------------------------------------------------------

def _state(frees):
    state = P.ClusterState(tuple(NodeSpec(g) for g in frees))
    return state


def test_packed_prefers_first_whole_fit():
    state = _state([4, 8, 8])
    assert P.get_placement("packed").place(state, 6) == ((1, 6),)
    # nothing fits whole: fill in index order
    assert P.get_placement("packed").place(state, 18) == ((0, 4), (1, 8),
                                                          (2, 6))


def test_best_fit_is_tightest_then_fewest_nodes():
    state = _state([8, 6, 8])
    # tightest single node that fits — not the first
    assert P.get_placement("best_fit").place(state, 6) == ((1, 6),)
    # must span: largest free blocks first (fewest nodes)
    state2 = _state([2, 8, 4])
    assert P.get_placement("best_fit").place(state2, 12) == ((1, 8), (2, 4))


def test_spread_balances_load():
    state = _state([8, 8])
    asg = P.get_placement("spread").place(state, 6)
    assert dict(asg) == {0: 3, 1: 3}
    # spanning status is derived from the actual split
    pl = P.Placement(0, asg)
    assert pl.spans and pl.w == 6
    assert not P.Placement(1, ((0, 6),)).spans


def test_fragmentation_forces_spanning_despite_fitting_capacity():
    """The point of the subsystem: 8 free GPUs exist but no node has 8,
    so an 8-gang *actually* spans — the old w > gpus_per_node shortcut
    (8 > 8 is False) would have called it intra-node."""
    state = _state([8, 8])
    state.assign(P.Placement(100, ((0, 4),)))
    state.assign(P.Placement(101, ((1, 4),)))
    asg = P.get_placement("best_fit").place(state, 8)
    assert P.Placement(2, asg).spans
    state.check_invariants(16)


# --------------------------------------------------------------------------
# ClusterState invariants
# --------------------------------------------------------------------------

def _exercise_state(strategy_name, node_gpus, gang_sizes):
    """Drive place/release traffic and check invariants at every step.
    Each gang places if it fits, and every third placement is released."""
    nodes = tuple(NodeSpec(g) for g in node_gpus)
    capacity = sum(node_gpus)
    state = P.ClusterState(nodes)
    strat = P.get_placement(strategy_name)
    live = []
    for k, w in enumerate(gang_sizes):
        w = 1 + (w % capacity)
        if w <= state.total_free():
            asg = strat.place(state, w)
            assert sum(g for _, g in asg) == w
            state.assign(P.Placement(k, asg))
            live.append(k)
        elif live and k % 3 == 0:
            state.release(live.pop(0))
        state.check_invariants(capacity)
    for jid in live:
        state.release(jid)
    state.check_invariants(capacity)
    assert state.total_free() == capacity


@pytest.mark.parametrize("strategy", ["packed", "spread", "best_fit"])
def test_no_oversubscription_deterministic(strategy):
    _exercise_state(strategy, [8, 4, 8, 2], [5, 3, 8, 1, 13, 2, 7, 9, 4,
                                             22, 1, 1, 6, 12, 3])


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(["packed", "spread", "best_fit"]),
       st.lists(st.integers(min_value=1, max_value=16), min_size=1,
                max_size=6),
       st.lists(st.integers(min_value=0, max_value=63), min_size=1,
                max_size=40))
def test_no_oversubscription_property(strategy, node_gpus, gang_sizes):
    """Hypothesis: under arbitrary place/release traffic no node is ever
    oversubscribed and granted GPUs are conserved, for every registered
    placement strategy."""
    _exercise_state(strategy, node_gpus, gang_sizes)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from(["packed", "spread", "best_fit"]))
def test_engine_conserves_gpus_across_events(seed, strategy):
    """Hypothesis: across a whole simulated trace on a fragmented cluster
    the placement engine's books always balance (checked at completion:
    everything released, free == capacity) and every job completes."""
    cluster = ClusterModel(capacity=32, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e8,
                           placement=strategy, defrag=True)
    jobs = make_workload("mixed_maxw", 12, 300.0, seed)
    res = simulate(jobs, strategy="precompute", cluster=cluster)
    assert len(res.completion_times) == 12
    assert not res.rejected


# --------------------------------------------------------------------------
# Flat no-op gate: golden values + sha256
# --------------------------------------------------------------------------

# avg JCT (hours) on synthetic_workload(60, 500.0, 0), capacity 64 — the
# pre-placement-engine values (tests/test_policies.py holds the same
# numbers for the plain flat cluster; the placement engine must reproduce
# them with the engine *active*).
GOLDEN_60JOB_JCT_HOURS = {
    "precompute": 1.871922560745595,
    "exploratory": 2.1010226326262185,
    "fixed_8": 2.0074955131017864,
    "srtf": 1.9517217772627014,
}

# sha256 of the sorted (job_id, completion_time) pairs of 1000-job
# precompute traces, capacity 64 — computed on main @ PR 3 (pre-placement)
# and frozen here: both the plain flat cluster and the flat cluster with
# the placement engine active must reproduce them bit-for-bit.
GOLDEN_1000JOB_SHA256 = {
    "bursty":
        "e214359fc3cb8d073c5b4e17f836ef652ab4b93a5a0ba130dba8a03950ff0302",
    "diurnal":
        "f38a4f3913b32c63193607be949be7743673249ac1dcd0b6d1b67763cdea708d",
    "heavy_tailed":
        "d7fed4c063aefcbda0323970f30265346627d035e0196f16687d1294c1cbbf8c",
    "mixed_maxw":
        "f38507e473d79f3e451a44ad1b3c9a8e9cf0985ed33e0b5d83a3f632f23dc0b6",
    "poisson":
        "68b1290f6eb5876e2d45c48fd4eb4f7653468b2eacd9acf6a46ce3eb0571dd25",
}

FLAT_PLACED = ClusterModel(capacity=64, placement="packed")


@pytest.fixture(scope="module")
def trace60():
    return synthetic_workload(60, 500.0, 0)


@pytest.mark.parametrize("strat", sorted(GOLDEN_60JOB_JCT_HOURS))
def test_flat_placement_preserves_60job_golden_values(trace60, strat):
    res = simulate(trace60, strategy=strat, cluster=FLAT_PLACED)
    assert res.avg_jct_hours == GOLDEN_60JOB_JCT_HOURS[strat], strat
    assert res.migrations == 0 and res.rejected == ()


def test_flat_placement_is_noop_for_every_registered_policy(trace60):
    """Every registry entry (including future ones): the placement engine
    on a flat cluster must be a bit-identical no-op, both engines."""
    for strat in S.registered_policies().values():
        plain = simulate(trace60, 64, strat)
        placed = simulate(trace60, strategy=strat, cluster=FLAT_PLACED)
        assert plain.completion_times == placed.completion_times, strat
        ref = simulate(trace60, strategy=strat, cluster=FLAT_PLACED,
                       engine="reference")
        assert placed.completion_times == ref.completion_times, strat


def _trace_sha256(res) -> str:
    payload = json.dumps(sorted(res.completion_times.items())).encode()
    return hashlib.sha256(payload).hexdigest()


@pytest.mark.parametrize("pattern", sorted(WORKLOAD_PATTERNS))
def test_1000job_sha256_parity_with_and_without_placement(pattern):
    want = GOLDEN_1000JOB_SHA256[pattern]
    jobs = make_workload(pattern, 1000, 250.0, 0)
    assert _trace_sha256(simulate(jobs, 64, "precompute")) == want, pattern
    placed = simulate(jobs, strategy="precompute", cluster=FLAT_PLACED)
    assert _trace_sha256(placed) == want, f"{pattern} with placement engine"


# --------------------------------------------------------------------------
# Placement clusters: engine parity, factors, defrag, admission
# --------------------------------------------------------------------------

FRAG = ClusterModel(capacity=32, gpus_per_node=8,
                    inter_node_beta=1.0 / 1.25e8,
                    contention_penalty=0.05,
                    placement="best_fit", defrag=True)


def test_placement_cluster_engine_parity_every_policy():
    jobs = make_workload("mixed_maxw", 20, 500.0, 7)
    for strat in S.registered_policies().values():
        fast = simulate(jobs, strategy=strat, cluster=FRAG)
        ref = simulate(jobs, strategy=strat, cluster=FRAG,
                       engine="reference")
        assert fast.completion_times == ref.completion_times, strat
        assert fast.migrations == ref.migrations, strat


def test_spanning_gang_pays_the_cross_node_factor():
    """Two w=8 gangs on 8-GPU nodes run intra-node; a w=16 gang must span
    and finishes later than the flat table predicts."""
    flat = ClusterModel(capacity=16)
    placed = ClusterModel(capacity=16, gpus_per_node=8,
                          inter_node_beta=1.0 / 1.25e8, placement="packed")
    one = [JobSpec(job_id=0, arrival=0.0, epochs=100.0, max_w=16)]
    t_flat = simulate(one, strategy="fixed_16", cluster=flat)
    t_span = simulate(one, strategy="fixed_16", cluster=placed)
    assert (t_span.completion_times[0] > t_flat.completion_times[0] * 1.2)
    # the same job as two node-sized gangs pays nothing
    intra = simulate([JobSpec(job_id=0, arrival=0.0, epochs=100.0)],
                     strategy="fixed_8", cluster=placed)
    intra_flat = simulate([JobSpec(job_id=0, arrival=0.0, epochs=100.0)],
                          strategy="fixed_8", cluster=flat)
    assert intra.completion_times == intra_flat.completion_times


def test_placement_factor_matches_legacy_spanning_scale():
    """The per-assignment factor times the flat table reproduces the
    legacy baked-in spanning row exactly (same analytic ratio)."""
    job = JobSpec(job_id=0, arrival=0.0, epochs=100.0, max_w=16)
    legacy = ClusterModel(capacity=16, gpus_per_node=8,
                          inter_node_beta=1.0 / 1.25e8)
    flat_tab = job.speed_table(16)
    legacy_tab = job.speed_table(legacy)
    factor = job.placement_factor(legacy, legacy.inter_hw())
    w = np.arange(9, 17)
    assert np.array_equal(flat_tab[w] * factor[w], legacy_tab[w])


def test_defrag_consolidates_and_charges_restart():
    """A gang left spanning by fragmentation is migrated to a single node
    once space frees up; the move is counted and the trace with defrag
    beats the one without."""
    on = simulate(make_workload("mixed_maxw", 20, 400.0, 5),
                  strategy="precompute", cluster=FRAG)
    off = simulate(make_workload("mixed_maxw", 20, 400.0, 5),
                   strategy="precompute",
                   cluster=dataclasses.replace(FRAG, defrag=False))
    assert on.migrations > 0
    assert off.migrations == 0
    assert on.avg_jct_hours < off.avg_jct_hours


def test_defrag_never_migrates_to_slower_node():
    """Consolidation must strictly beat the current placement factor: a
    heterogeneous fleet can free up a node so slow that staying spanned
    across fast nodes is faster — paying restart_cost to get slower is
    never a defrag."""
    ancient = dataclasses.replace(INFINIBAND_100G, gamma=1000.0 / 50e9,
                                  name="ancient")
    hetero = ClusterModel(capacity=16,
                          nodes=(NodeSpec(4), NodeSpec(4),
                                 NodeSpec(8, hw=ancient)),
                          inter_node_beta=1.0 / 1.25e9,
                          placement="packed", defrag=True)
    spec = JobSpec(job_id=0, arrival=0.0, epochs=10.0, max_w=16)
    eng = P.PlacementEngine(hetero)
    eng.register(spec)
    eng.state.assign(P.Placement(0, ((0, 3), (1, 3))))
    eng.apply([0], [6], [])
    assert eng.migrations == 0          # the slow node fits but is slower
    assert eng.state.placements[0].assignment == ((0, 3), (1, 3))
    # homogeneous twin: the same gang does consolidate
    homog = ClusterModel(capacity=16,
                         nodes=(NodeSpec(4), NodeSpec(4), NodeSpec(8)),
                         inter_node_beta=1.0 / 1.25e9,
                         placement="packed", defrag=True)
    eng2 = P.PlacementEngine(homog)
    eng2.register(spec)
    eng2.state.assign(P.Placement(0, ((0, 3), (1, 3))))
    eng2.apply([0], [6], [])
    assert eng2.migrations == 1
    assert eng2.state.placements[0].assignment == ((2, 6),)


def test_queue_cap_rejects_and_records():
    adm = ClusterModel(capacity=16, placement="packed",
                       admission="queue_cap_4")
    jobs = make_workload("bursty", 30, 100.0, 1)
    res = simulate(jobs, strategy="precompute", cluster=adm)
    ref = simulate(jobs, strategy="precompute", cluster=adm,
                   engine="reference")
    assert res.rejected == ref.rejected
    assert len(res.rejected) > 0
    assert len(res.completion_times) + len(res.rejected) == 30
    assert set(res.rejected).isdisjoint(res.completion_times)
    assert res.peak_concurrency <= 4


def test_free_gpus_delays_but_completes_everything():
    adm = ClusterModel(capacity=16, placement="packed",
                       admission="free_gpus_8")
    jobs = make_workload("bursty", 30, 100.0, 1)
    res = simulate(jobs, strategy="precompute", cluster=adm)
    ref = simulate(jobs, strategy="precompute", cluster=adm,
                   engine="reference")
    assert res.completion_times == ref.completion_times
    assert len(res.completion_times) == 30 and res.rejected == ()
    # backpressure means strictly fewer concurrent jobs than admit-all
    free = simulate(jobs, strategy="precompute",
                    cluster=ClusterModel(capacity=16, placement="packed"))
    assert res.peak_concurrency <= free.peak_concurrency


def test_heterogeneous_nodes_slow_gangs_on_old_hosts():
    """A job packed onto a quarter-speed node finishes later than one on
    a current-gen node; node order is the packed preference order."""
    slow_hw = dataclasses.replace(INFINIBAND_100G, beta=4.0 / 12.5e9,
                                  gamma=4.0 / 50e9, name="ib_25g_class")
    fast_first = ClusterModel(
        capacity=16, nodes=(NodeSpec(8), NodeSpec(8, hw=slow_hw)),
        inter_node_beta=1.0 / 1.25e8, placement="packed")
    slow_first = ClusterModel(
        capacity=16, nodes=(NodeSpec(8, hw=slow_hw), NodeSpec(8)),
        inter_node_beta=1.0 / 1.25e8, placement="packed")
    one = [JobSpec(job_id=0, arrival=0.0, epochs=100.0)]
    t_fast = simulate(one, strategy="fixed_8", cluster=fast_first)
    t_slow = simulate(one, strategy="fixed_8", cluster=slow_first)
    assert t_slow.completion_times[0] > t_fast.completion_times[0]
    # parity on the heterogeneous fleet too
    jobs = make_workload("poisson", 15, 400.0, 2)
    for cl in (fast_first, slow_first):
        fast = simulate(jobs, strategy="precompute", cluster=cl)
        ref = simulate(jobs, strategy="precompute", cluster=cl,
                       engine="reference")
        assert fast.completion_times == ref.completion_times


# --------------------------------------------------------------------------
# Placement-aware policies (pack_*) and the Table-3 acceptance scenario
# --------------------------------------------------------------------------

def test_pack_policy_spec_parsing():
    assert S.get_policy("pack_srtf").spec == "pack_srtf"
    assert S.get_policy("pack_precompute").spec == "pack_precompute"
    # longest-prefix parsing handles multi-underscore inner specs
    assert S.get_policy("pack_utility_greedy").spec == "pack_utility_greedy"
    assert S.get_policy("pack_fixed_8").spec == "pack_fixed_8"
    with pytest.raises(ValueError, match="wraps another policy"):
        S.get_policy("pack")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        S.get_policy("pack_bogus")


def test_pack_policy_clamps_to_largest_node():
    jobs = [JobSpec(job_id=j, arrival=0.0, epochs=150.0, max_w=16)
            for j in range(2)]
    tables = np.stack([s.speed_table(32) for s in jobs])
    view = S.AllocView(remaining=np.array([150.0, 150.0]), tables=tables,
                       max_w=np.array([16, 16], np.int64),
                       explore_started=np.full(2, -np.inf))
    cluster = ClusterModel(capacity=32, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e8,
                           placement="packed")
    target = S.get_policy("pack_srtf").allocate(view, cluster, 0.0)
    assert (target <= 8).all()
    # on a flat cluster the clamp is the capacity: identical to inner
    flat = ClusterModel(capacity=32)
    a = S.get_policy("pack_srtf").allocate(view, flat, 0.0)
    b = S.get_policy("srtf").allocate(view, flat, 0.0)
    assert np.array_equal(a, b)


def test_alloc_view_carries_placement_snapshot():
    """Policies see per-node free GPUs under a placement engine (the hook
    placement-aware strategies build on)."""
    seen = {}

    class Probe(S.SchedulingPolicy):
        spec = "probe"

        def allocate(self, state, cluster, now):
            if state.placement is not None:
                seen["free"] = state.placement.free.copy()
                seen["node_gpus"] = state.placement.node_gpus
                seen["strategy"] = state.placement.strategy
            return np.ones(state.n, np.int64)

    jobs = [JobSpec(job_id=0, arrival=0.0, epochs=1.0)]
    cluster = ClusterModel(capacity=16, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e8,
                           placement="best_fit")
    simulate(jobs, strategy=Probe(), cluster=cluster)
    assert seen["strategy"] == "best_fit"
    assert seen["node_gpus"].tolist() == [8, 8]
    assert seen["free"].tolist() == [8, 8]       # snapshot before placing


def test_placement_aware_beats_blind_on_fragmented_scenario():
    """The PR-4 acceptance row: on the fragmented Table-3 placement
    scenario a placement-aware strategy beats the placement-blind
    baseline by a wide margin."""
    from benchmarks.table3_scheduler_sim import (FRAGMENTED,
                                                 HETEROGENEOUS)
    jobs = make_workload("mixed_maxw", 60, 500.0, 0)
    for cluster in (FRAGMENTED, HETEROGENEOUS):
        blind = simulate(jobs, strategy="srtf", cluster=cluster)
        aware = simulate(jobs, strategy="pack_srtf", cluster=cluster)
        assert aware.avg_jct_hours < blind.avg_jct_hours, cluster.placement
    frag = {s: simulate(jobs, strategy=s, cluster=FRAGMENTED).avg_jct_hours
            for s in ("precompute", "pack_precompute")}
    assert frag["pack_precompute"] < frag["precompute"]
