"""Convergence (eq. 1) and resource (eq. 5) model fitting tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.convergence import ConvergenceModel, fit_convergence, nnls
from repro.core.resource_model import fit_resource_model


def test_nnls_matches_scipy():
    from scipy.optimize import nnls as scipy_nnls
    rng = np.random.default_rng(0)
    for _ in range(5):
        A = rng.normal(size=(20, 4))
        b = rng.normal(size=20)
        x = nnls(A, b)
        x_ref, _ = scipy_nnls(A, b)
        assert np.all(x >= -1e-12)
        # objective values agree
        assert (np.linalg.norm(A @ x - b)
                <= np.linalg.norm(A @ x_ref - b) + 1e-6)


@settings(max_examples=10, deadline=None)
@given(b0=st.floats(1e-4, 1e-2), b1=st.floats(0.1, 2.0),
       b2=st.floats(0.0, 0.5))
def test_fit_recovers_synthetic_curve(b0, b1, b2):
    true = ConvergenceModel(b0, b1, b2)
    k = np.linspace(1, 2000, 60)
    l = true.loss_at(k)
    fit = fit_convergence(k, l)
    np.testing.assert_allclose(fit.loss_at(k), l, rtol=0.08, atol=0.02)


def test_steps_to_loss():
    m = ConvergenceModel(1e-3, 1.0, 0.1)
    target = 0.2
    k = m.steps_to_loss(target)
    assert abs(m.loss_at(k) - target) < 1e-9
    assert m.steps_to_loss(0.05) == np.inf  # below asymptote


def test_fit_noisy_resnet_like_curve():
    rng = np.random.default_rng(0)
    true = ConvergenceModel(2e-3, 0.5, 0.3)
    k = np.arange(10, 3000, 25)
    l = true.loss_at(k) * (1 + rng.normal(scale=0.03, size=k.size))
    fit = fit_convergence(k, l)
    # remaining-steps prediction within 30% at a mid-curve target
    target = true.loss_at(2000.0)
    assert abs(fit.steps_to_loss(target) - 2000) / 2000 < 0.3


def test_resource_model_fit_recovers_speeds():
    theta = np.array([1.0, 0.01, 2e-7, 0.02])
    m, n = 128, 6.9e6
    ws = np.array([1, 2, 4, 8, 16])
    secs = (theta[0] * m / ws + theta[1] * (ws - 1)
            + theta[2] * (ws - 1) * n / ws + theta[3])
    model = fit_resource_model(ws, 1.0 / secs, m, n)
    np.testing.assert_allclose(model.f(ws), 1.0 / secs, rtol=1e-3)
    assert np.all(model.theta >= 0)


def test_resource_model_monotone_speed():
    """Fitted to the paper's Table-2 points, f(w) must increase on [1, 8]
    (more workers, more epochs/sec)."""
    from repro.core.jobs import _table2_model
    m = _table2_model()
    f = m.f(np.arange(1, 9))
    assert np.all(np.diff(f) > 0)
