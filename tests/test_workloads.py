"""Workload-pattern library (jobs.py): determinism, arrival monotonicity,
pattern-specific shape properties, engine parity per pattern, and
thousand-job-scale smoke runs of the SoA simulator."""
import numpy as np
import pytest

from repro.core.jobs import (WORKLOAD_PATTERNS, bursty_workload,
                             diurnal_workload, heavy_tailed_workload,
                             make_workload, mixed_maxw_workload,
                             synthetic_workload)
from repro.core.simulator import simulate

PATTERNS = sorted(WORKLOAD_PATTERNS)


# --------------------------------------------------------------------------
# Library-wide contracts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", PATTERNS)
def test_deterministic_per_seed(pattern):
    a = make_workload(pattern, 50, 400.0, seed=7)
    b = make_workload(pattern, 50, 400.0, seed=7)
    assert [(j.arrival, j.epochs, j.max_w) for j in a] == \
           [(j.arrival, j.epochs, j.max_w) for j in b]
    c = make_workload(pattern, 50, 400.0, seed=8)
    assert [(j.arrival, j.epochs) for j in a] != \
           [(j.arrival, j.epochs) for j in c]


@pytest.mark.parametrize("pattern", PATTERNS)
def test_arrivals_monotone_ids_sequential(pattern):
    jobs = make_workload(pattern, 80, 300.0, seed=2)
    assert len(jobs) == 80
    arrivals = [j.arrival for j in jobs]
    assert all(a <= b for a, b in zip(arrivals, arrivals[1:]))
    assert arrivals[0] > 0.0
    assert [j.job_id for j in jobs] == list(range(80))
    assert all(j.epochs > 0 for j in jobs)


@pytest.mark.parametrize("pattern", PATTERNS)
def test_long_run_rate_matches_gap(pattern):
    """Every pattern keeps the average interarrival near the requested gap
    so per-pattern JCTs are comparable at a given contention level."""
    gap = 300.0
    jobs = make_workload(pattern, 600, gap, seed=11)
    mean_gap = jobs[-1].arrival / len(jobs)
    assert 0.6 * gap < mean_gap < 1.6 * gap, mean_gap


def test_unknown_pattern_rejected():
    with pytest.raises(ValueError, match="unknown workload pattern"):
        make_workload("fractal", 10, 100.0, 0)


def test_poisson_pattern_is_the_paper_trace():
    """make_workload('poisson') must stay bit-identical to the §7 generator
    Table 3 is built on."""
    via_registry = make_workload("poisson", 40, 500.0, 0)
    direct = synthetic_workload(40, 500.0, 0)
    assert [(j.arrival, j.epochs) for j in via_registry] == \
           [(j.arrival, j.epochs) for j in direct]


# --------------------------------------------------------------------------
# Pattern-specific shape properties
# --------------------------------------------------------------------------

def test_bursty_arrivals_cluster():
    jobs = bursty_workload(200, 300.0, seed=3, burst_mean=5.0)
    arrivals = [j.arrival for j in jobs]
    n_instants = len(set(arrivals))
    # bursts land at a single instant: far fewer distinct arrival times
    # than jobs, and the mean burst size is near burst_mean
    assert n_instants < len(jobs) // 2
    assert 2.0 < len(jobs) / n_instants < 10.0
    # at least one burst is large enough to slam the scheduler at once
    _, counts = np.unique(arrivals, return_counts=True)
    assert counts.max() >= 8


def test_diurnal_rate_modulates_with_phase():
    period = 86_400.0
    jobs = diurnal_workload(2000, 200.0, seed=4, period=period,
                            amplitude=0.75)
    phase = np.array([j.arrival % period for j in jobs])
    # sin > 0 (higher rate) over the first half-period
    hi = int((phase < period / 2).sum())
    lo = len(jobs) - hi
    assert hi > 1.5 * lo, (hi, lo)


def test_diurnal_amplitude_validated():
    with pytest.raises(ValueError, match="amplitude"):
        diurnal_workload(10, 100.0, 0, amplitude=1.2)


def test_heavy_tailed_epochs_pareto():
    jobs = heavy_tailed_workload(1500, 300.0, seed=5, alpha=1.8,
                                 epoch_scale=60.0, epoch_cap=2000.0)
    epochs = np.array([j.epochs for j in jobs])
    assert epochs.min() >= 60.0          # classic Pareto: x >= x_m
    assert epochs.max() <= 2000.0        # cap respected
    # heavy tail: the max dwarfs the median, mean >> median
    assert np.median(epochs) < 120.0
    assert epochs.max() > 10 * np.median(epochs)
    assert epochs.mean() > 1.2 * np.median(epochs)


def test_mixed_maxw_fleet_heterogeneous():
    jobs = mixed_maxw_workload(120, 300.0, seed=6, maxw_choices=(2, 4, 8, 16))
    caps = {j.max_w for j in jobs}
    assert caps <= {2, 4, 8, 16}
    assert len(caps) >= 3                # genuinely mixed fleet
    # other patterns keep the paper's single-node cap
    assert all(j.max_w == 8 for j in synthetic_workload(10, 300.0, 6))


def test_mixed_maxw_caps_enforced_by_scheduler():
    """The simulator must honor per-job caps: in a 2-job fleet with ample
    capacity, the capped job stays at its max_w while the big job scales
    out — the whole point of the mixed_maxw pattern."""
    from repro.core.jobs import JobSpec

    jobs = [JobSpec(job_id=0, arrival=1.0, epochs=150.0, max_w=2),
            JobSpec(job_id=1, arrival=1.0, epochs=150.0, max_w=16)]
    res = simulate(jobs, 32, "precompute")
    ref = simulate(jobs, 32, "precompute", engine="reference")
    assert res.completion_times == ref.completion_times
    # same work, same arrival: the max_w=16 job finishes strictly first
    assert res.completion_times[1] < res.completion_times[0]
    # and the capped job ran at exactly w=2 between restarts:
    # JCT ~ restart + epochs / speed(2)
    spec = jobs[0]
    expect = 1.0 + 10.0 + 150.0 / spec.speed(2)
    assert abs(res.completion_times[0] - expect) < 15.0


# --------------------------------------------------------------------------
# Simulator integration: engine parity per pattern + 1000-job scale
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", PATTERNS)
def test_engines_bit_identical_per_pattern(pattern):
    """The SoA engine must reproduce the reference event loop bit-for-bit
    on every workload pattern, not just the paper's Poisson trace."""
    jobs = make_workload(pattern, 25, 400.0, seed=9)
    for strat in ("precompute", "exploratory", "fixed_4"):
        fast = simulate(jobs, 32, strat, engine="table")
        ref = simulate(jobs, 32, strat, engine="reference")
        assert fast.completion_times == ref.completion_times, (pattern,
                                                               strat)
        assert fast.peak_concurrency == ref.peak_concurrency, (pattern,
                                                               strat)


@pytest.mark.parametrize("strategy", ["precompute", "exploratory",
                                      "fixed_8"])
def test_1000_job_trace_completes(strategy):
    """Thousand-job Poisson trace per strategy: every job admitted and
    completed after its arrival, and peak concurrency stays bounded."""
    jobs = synthetic_workload(1000, 250.0, seed=0)
    res = simulate(jobs, 64, strategy)
    assert len(res.completion_times) == 1000
    arr = {j.job_id: j.arrival for j in jobs}
    assert all(res.completion_times[j] > arr[j]
               for j in res.completion_times)
    assert res.peak_concurrency <= 1000
    assert res.avg_jct_hours > 0.0


@pytest.mark.parametrize("pattern", [p for p in PATTERNS if p != "poisson"])
def test_1000_job_trace_completes_per_pattern(pattern):
    jobs = make_workload(pattern, 1000, 250.0, seed=0)
    res = simulate(jobs, 64, "precompute")
    assert len(res.completion_times) == 1000
