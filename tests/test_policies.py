"""Policy registry + ClusterModel: spec parsing/validation, registry
round-trips (every registered policy runs a 60-job trace, engines
bit-identical, pre-refactor completion times preserved), the two new
policies (SRTF, GADGET-style utility greedy), and the non-flat cluster
scenario (multi-node topology + contention penalty)."""
import numpy as np
import pytest

from repro.collectives.cost import ClusterModel
from repro.core import scheduler as S
from repro.core.jobs import JobSpec, synthetic_workload
from repro.core.simulator import simulate


# --------------------------------------------------------------------------
# Spec parsing + validation
# --------------------------------------------------------------------------

def test_get_policy_resolves_all_registered_examples():
    for name, example in S.registered_policies().items():
        policy = S.get_policy(example)
        assert isinstance(policy, S.SchedulingPolicy)
        assert policy.spec == example
        assert repr(policy)        # repr never raises


def test_get_policy_passthrough_and_identity():
    p = S.FixedPolicy(4)
    assert S.get_policy(p) is p
    assert S.get_policy("fixed_16").k == 16


@pytest.mark.parametrize("bad,match", [
    ("fixed", "needs an integer parameter"),
    ("fixed_x", "must be an integer"),
    ("fixed_0", "must be >= 1"),
    ("fixed_-1", "must be >= 1"),
    ("bogus", "unknown scheduling policy"),
    ("precompute_3", "takes no parameter"),
    ("utility_greedy_3", "takes no parameter"),
    ("", "non-empty string"),
])
def test_malformed_specs_fail_loudly(bad, match):
    """The old engine died inside str.split/int() on these; the registry
    rejects them up front with an actionable message."""
    with pytest.raises(ValueError, match=match):
        S.get_policy(bad)


def test_register_policy_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        S.register_policy("fixed", lambda p: S.FixedPolicy(1))


def test_unknown_policy_error_lists_registry():
    with pytest.raises(ValueError, match="precompute"):
        S.get_policy("no_such_policy")


# --------------------------------------------------------------------------
# Registry round-trip: pre-refactor parity on the 60-job trace
# --------------------------------------------------------------------------

# avg JCT (hours) produced by the pre-registry implementation (main @ PR 2)
# on synthetic_workload(60, 500.0, 0), capacity 64 — the refactor must
# reproduce these bit-for-bit on a flat homogeneous cluster.
PRE_REFACTOR_JCT_HOURS = {
    "precompute": 1.871922560745595,
    "exploratory": 2.1010226326262185,
    "fixed_8": 2.0074955131017864,
    "fixed_4": 2.1384725028154628,
    "fixed_2": 3.5068568497974564,
    "fixed_1": 6.250871048451913,
}


@pytest.fixture(scope="module")
def trace60():
    return synthetic_workload(60, 500.0, 0)


@pytest.mark.parametrize("strat", sorted(PRE_REFACTOR_JCT_HOURS))
def test_pre_refactor_completion_times_preserved(trace60, strat):
    res = simulate(trace60, 64, strat)
    assert res.avg_jct_hours == PRE_REFACTOR_JCT_HOURS[strat], strat


def test_every_registered_policy_round_trips(trace60):
    """Every registry entry (including future ones) must complete the
    60-job trace with table/reference engine bit-identity."""
    for strat in S.registered_policies().values():
        fast = simulate(trace60, 64, strat, engine="table")
        ref = simulate(trace60, 64, strat, engine="reference")
        assert len(fast.completion_times) == 60, strat
        assert fast.completion_times == ref.completion_times, strat
        assert fast.peak_concurrency == ref.peak_concurrency, strat
        assert fast.strategy == strat


# --------------------------------------------------------------------------
# The new policies
# --------------------------------------------------------------------------

def _view(specs, remaining=None, width=16):
    tables = np.stack([s.speed_table(width) for s in specs])
    return S.AllocView(
        remaining=np.array([s.epochs for s in specs] if remaining is None
                           else remaining, float),
        tables=tables,
        max_w=np.array([s.max_w for s in specs], np.int64),
        explore_started=np.full(len(specs), -np.inf))


def test_srtf_prioritizes_shortest_job():
    """With capacity for only one job, SRTF runs the job with the least
    remaining service time and leaves the longer one at 0."""
    short = JobSpec(job_id=0, arrival=0.0, epochs=10.0)
    long = JobSpec(job_id=1, arrival=0.0, epochs=500.0)
    view = _view([long, short], remaining=[500.0, 10.0], width=8)
    target = S.SRTFPolicy().allocate(view, ClusterModel(capacity=8), 0.0)
    assert target[1] >= 1          # the short job runs...
    assert target[0] == 0          # ...the long one waits
    # capacity respected with more jobs than GPUs
    many = [JobSpec(job_id=j, arrival=0.0, epochs=float(100 + j))
            for j in range(6)]
    t = S.SRTFPolicy().allocate(_view(many), ClusterModel(capacity=4), 0.0)
    assert t.sum() <= 4


def test_srtf_respects_per_job_caps():
    jobs = [JobSpec(job_id=0, arrival=0.0, epochs=10.0, max_w=2),
            JobSpec(job_id=1, arrival=0.0, epochs=20.0, max_w=8)]
    t = S.SRTFPolicy().allocate(_view(jobs), ClusterModel(capacity=32), 0.0)
    assert t[0] <= 2 and t[1] <= 8


def test_utility_greedy_is_size_blind_and_pow2():
    """GADGET-style utility: the target depends only on the speed tables,
    never on remaining work — and doubling keeps allocations at powers of
    two."""
    specs = [JobSpec(job_id=j, arrival=0.0, epochs=150.0) for j in range(4)]
    cluster = ClusterModel(capacity=16)
    pol = S.UtilityGreedyPolicy()
    a = pol.allocate(_view(specs, remaining=[1.0, 10.0, 100.0, 1000.0]),
                     cluster, 0.0)
    b = pol.allocate(_view(specs, remaining=[1000.0, 100.0, 10.0, 1.0]),
                     cluster, 0.0)
    assert np.array_equal(a, b)                   # Q-blind
    assert all(w == 0 or (w & (w - 1)) == 0 for w in a)   # pow2 invariant
    assert a.sum() <= cluster.capacity
    assert pol.static                             # solve reuse is sound


def test_utility_greedy_respects_caps_and_fifo():
    specs = [JobSpec(job_id=j, arrival=0.0, epochs=150.0, max_w=2)
             for j in range(3)]
    t = S.UtilityGreedyPolicy().allocate(_view(specs),
                                         ClusterModel(capacity=32), 0.0)
    assert (t <= 2).all() and (t >= 1).all()
    # oversubscribed: FIFO — later jobs get 0 first
    many = [JobSpec(job_id=j, arrival=0.0, epochs=150.0) for j in range(6)]
    t = S.UtilityGreedyPolicy().allocate(_view(many),
                                         ClusterModel(capacity=4), 0.0)
    assert (t[:4] >= 1).all() and (t[4:] == 0).all()


def test_new_policies_complete_heavy_tailed_trace():
    """SRTF's home turf: heavy-tailed job sizes.  Both new policies must
    finish the trace on both engines, bit-identically."""
    from repro.core.jobs import make_workload
    jobs = make_workload("heavy_tailed", 30, 400.0, 3)
    for strat in ("srtf", "utility_greedy"):
        fast = simulate(jobs, 32, strat)
        ref = simulate(jobs, 32, strat, engine="reference")
        assert len(fast.completion_times) == 30, strat
        assert fast.completion_times == ref.completion_times, strat


# --------------------------------------------------------------------------
# ClusterModel: validation, topology tables, contention
# --------------------------------------------------------------------------

def test_cluster_model_validation():
    with pytest.raises(ValueError, match="capacity must be"):
        ClusterModel(capacity=0)
    with pytest.raises(ValueError, match="inter_node_beta"):
        ClusterModel(gpus_per_node=8)
    with pytest.raises(ValueError, match="gpus_per_node"):
        ClusterModel(gpus_per_node=0, inter_node_beta=1e-9)
    with pytest.raises(ValueError, match="faster than the intra-node"):
        ClusterModel(gpus_per_node=8, inter_node_beta=1e-12)
    with pytest.raises(ValueError, match="without gpus_per_node"):
        ClusterModel(inter_node_beta=1e-9)     # forgot the node size
    with pytest.raises(ValueError, match="contention_penalty"):
        ClusterModel(contention_penalty=-0.1)


def test_cluster_model_contention_factor():
    cm = ClusterModel(contention_penalty=0.5)
    assert cm.contention_factor(0) == cm.contention_factor(1) == 1.0
    assert cm.contention_factor(2) == pytest.approx(1.0 / 1.5)
    assert cm.contention_factor(3) == pytest.approx(0.5)
    assert ClusterModel().contention_factor(10) == 1.0


def test_flat_cluster_model_is_bit_identical_to_capacity_int(trace60):
    flat = ClusterModel(capacity=64)
    assert flat.is_flat
    for strat in ("precompute", "fixed_8"):
        a = simulate(trace60, 64, strat)
        b = simulate(trace60, strategy=strat, cluster=flat)
        assert a.completion_times == b.completion_times, strat


def test_topology_speed_table_scales_spanning_rows():
    job = JobSpec(job_id=0, arrival=0.0, epochs=150.0)
    topo = ClusterModel(capacity=16, gpus_per_node=4,
                        inter_node_beta=1.0 / 1.25e9)
    flat_tab = job.speed_table(16)
    topo_tab = job.speed_table(topo)
    assert np.array_equal(topo_tab[:5], flat_tab[:5])    # intra-node rows
    assert (topo_tab[5:] < flat_tab[5:]).all()           # spanning rows pay
    assert job.speed_table(topo) is topo_tab             # cached per cluster
    # flat ClusterModel shares the int-path cache outright
    assert job.speed_table(ClusterModel(capacity=16)) is flat_tab


def test_multinode_contention_scenario_engine_parity():
    """The acceptance scenario: multi-node topology + contention penalty.
    Both engines agree bit-for-bit and the non-flat cluster is never
    faster than the flat one."""
    cluster = ClusterModel(capacity=32, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e9,
                           contention_penalty=0.1)
    jobs = synthetic_workload(20, 500.0, 11)
    for strat in S.registered_policies().values():
        fast = simulate(jobs, strategy=strat, cluster=cluster)
        ref = simulate(jobs, strategy=strat, cluster=cluster,
                       engine="reference")
        assert fast.completion_times == ref.completion_times, strat
        flat = simulate(jobs, 32, strat)
        assert fast.avg_jct_hours >= flat.avg_jct_hours - 1e-9, strat


def test_contention_slows_concurrent_ring_jobs():
    """Two overlapping w>=2 jobs under a contention penalty finish later
    than without one; a single job (k=1) is unaffected."""
    cont = ClusterModel(capacity=16, contention_penalty=0.5)
    two = [JobSpec(job_id=0, arrival=0.0, epochs=100.0),
           JobSpec(job_id=1, arrival=0.0, epochs=100.0)]
    base = simulate(two, 16, "fixed_8")
    hit = simulate(two, strategy="fixed_8", cluster=cont)
    assert hit.avg_jct_hours > base.avg_jct_hours * 1.3
    solo = [JobSpec(job_id=0, arrival=0.0, epochs=100.0)]
    assert (simulate(solo, strategy="fixed_8", cluster=cont).avg_jct_hours
            == simulate(solo, 16, "fixed_8").avg_jct_hours)


def test_run_table3_multinode_rows():
    """run_table3 accepts a ClusterModel and produces rows for the new
    policies alongside the paper's six."""
    from repro.core.simulator import run_table3
    cluster = ClusterModel(capacity=64, gpus_per_node=8,
                           inter_node_beta=1.0 / 1.25e9,
                           contention_penalty=0.05)
    out = run_table3(seed=0, contention={"tiny": (500.0, 12)},
                     cluster=cluster)
    row = out["tiny"]
    for strat in ("precompute", "fixed_8", "srtf", "utility_greedy"):
        assert strat in row and row[strat] > 0.0


def test_simresult_strategy_is_canonical_spec(trace60):
    res = simulate(trace60[:5], 64, S.FixedPolicy(2))
    assert res.strategy == "fixed_2"


def test_conflicting_capacity_and_cluster_rejected(trace60):
    """Passing both a capacity and a cluster of a different size is a
    loud error, not a silently mis-scaled experiment."""
    with pytest.raises(ValueError, match="conflicting cluster size"):
        simulate(trace60[:5], 32, "precompute",
                 cluster=ClusterModel(capacity=64))
    # agreeing sizes are fine
    res = simulate(trace60[:5], 64, "precompute",
                   cluster=ClusterModel(capacity=64))
    assert len(res.completion_times) == 5
