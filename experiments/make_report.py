"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
experiments/dryrun/*.json.

  PYTHONPATH=src python experiments/make_report.py > experiments/roofline.md
"""
import glob
import json
import os
import sys

DIR = os.path.join(os.path.dirname(__file__), "dryrun")

ARCH_ORDER = ["qwen2.5-3b", "qwen2-vl-2b", "h2o-danube-1.8b", "mamba2-780m",
              "jamba-v0.1-52b", "qwen3-moe-30b-a3b", "gemma-2b", "dbrx-132b",
              "whisper-base", "qwen2.5-14b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for fn in glob.glob(os.path.join(DIR, "*.json")):
        r = json.load(open(fn))
        recs[(r["arch"], r["shape"], r["mesh"], r["profile"])] = r
    return recs


def fmt_ms(s):
    return f"{s*1e3:.1f}" if s >= 1e-4 else f"{s*1e3:.3f}"


def roofline_table(recs, mesh="16x16", profile="baseline"):
    print(f"\n### Roofline — mesh {mesh} ({profile})\n")
    print("| arch | shape | mem/dev GiB | compute ms | memory ms | "
          "collective ms | dominant | MODEL_FLOPS/HLO | per-step bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, profile))
            if not r:
                print(f"| {arch} | {shape} | — | — | — | — | — | — | — |")
                continue
            roof = r["roofline"]
            u = r["useful_flops_ratio"]
            bound = max(roof["compute_s"], roof["memory_s"],
                        roof["collective_s"])
            print(f"| {arch} | {shape} | "
                  f"{r['memory']['peak_bytes_per_device']/2**30:.2f} | "
                  f"{fmt_ms(roof['compute_s'])} | {fmt_ms(roof['memory_s'])} | "
                  f"{fmt_ms(roof['collective_s'])} | {roof['dominant']} | "
                  f"{u:.3f} | {fmt_ms(bound)} |" if u is not None else
                  f"| {arch} | {shape} | ... |")


def dryrun_table(recs):
    print("\n### Dry-run compile proof (all combos)\n")
    print("| arch | shape | 16x16 | 2x16x16 | window | params |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r1 = recs.get((arch, shape, "16x16", "baseline"))
            r2 = recs.get((arch, shape, "2x16x16", "baseline"))
            w = r1 and r1.get("window")
            p = r1 and f"{r1['params_total']/1e9:.2f}B"
            ok1 = "OK" if r1 else "—"
            ok2 = "OK" if r2 else "—"
            print(f"| {arch} | {shape} | {ok1} | {ok2} | {w} | {p} |")


def collective_mix(recs, mesh="16x16"):
    print(f"\n### Collective mix (GB per device per step, {mesh})\n")
    print("| arch | shape | all-reduce | all-gather | reduce-scatter | "
          "all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    kinds = ["all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, "baseline"))
            if not r:
                continue
            c = r["roofline"]["collectives"]
            cells = " | ".join(f"{c.get(k, 0)/2**30:.2f}" for k in kinds)
            print(f"| {arch} | {shape} | {cells} |")


SCHED_DIR = os.path.join(os.path.dirname(__file__), "scheduler")


def scheduler_rollup_table(sched_dir=SCHED_DIR):
    """§Scheduler telemetry: one row per metrics-rollup JSON dropped in
    experiments/scheduler/ (written by ``examples/scheduler_sim.py
    --rollup-out`` or any ``TelemetryResult.rollup()`` dump)."""
    files = sorted(glob.glob(os.path.join(sched_dir, "*.json")))
    if not files:
        return
    print("\n### Scheduler telemetry rollups\n")
    print("| run | policy | jobs | makespan h | util | goodput | "
          "avg JCT h | queue peak | rejected | migrations | evictions |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for fn in files:
        r = json.load(open(fn))
        util = r.get("utilization")
        # goodput is None on idle runs and absent from pre-PR-10 rollups
        good = r.get("goodput")
        print(f"| {os.path.splitext(os.path.basename(fn))[0]} "
              f"| {r.get('policy', '?')} | {r.get('n_jobs', 0)} "
              f"| {r.get('makespan', 0.0)/3600.0:.2f} "
              f"| {'—' if util is None else f'{util:.3f}'} "
              f"| {'—' if good is None else f'{good:.3f}'} "
              f"| {r.get('avg_jct_s', 0.0)/3600.0:.2f} "
              f"| {r.get('queue_peak', 0)} | {r.get('n_rejected', 0)} "
              f"| {r.get('n_migrations', 0)} "
              f"| {r.get('n_evictions', 0)} |")


if __name__ == "__main__":
    recs = load()
    sys.stderr.write(f"{len(recs)} records\n")
    dryrun_table(recs)
    roofline_table(recs, "16x16")
    roofline_table(recs, "2x16x16")
    collective_mix(recs)
    scheduler_rollup_table()
